"""Adaptive aggregation frequency (paper Figs 4/5/8): the DQN+Lyapunov
agent against fixed frequencies under a resource budget in a time-varying
channel — the paper's headline experiment, run **sync-free** end to end on
the in-jit control plane:

  * Alg.-1 DQN training lowers into one nested `lax.scan` over the
    DT-simulated environment (`repro.control.scanned_dqn`, triggered by the
    `dqn` controller registry factory);
  * every federation runs `execution="scanned"`: K asynchronous cluster
    rounds, the controller's `select`, and the Eqn-12 deficit queue compile
    into a single `lax.scan` — device metrics cross to the host once.

    PYTHONPATH=src python examples/adaptive_frequency.py
"""
import repro.api as api
from repro.api import ControllerSpec, Federation, FederationSpec, FleetSpec

ROUNDS = 40

BASE = FederationSpec(
    fleet=FleetSpec(n_devices=16, dt_max_dev=0.2),
    clustering=api.ClusteringSpec(n_clusters=4),
    channel=api.ChannelSpec(p_good=0.4),
    task=api.TaskSpec("mlp", {"n_samples": 2048, "dim": 64}),
    execution="scanned", rounds=ROUNDS,
    sim_seconds=1e9, local_batch=32, seed=0)


def run(name: str, controller: ControllerSpec):
    trace = Federation.from_spec(
        BASE.replace(controller=controller)).run()
    final = trace.records[-1]                   # the appended eval record
    print(f"{name},{final.loss:.4f},{final.acc:.3f},{final.energy:.1f}")
    return trace


def main():
    print(f"scheme,final_loss,final_acc,energy   ({ROUNDS} scanned rounds)")
    run("dqn_adaptive",
        ControllerSpec("dqn", {"episodes": 4, "horizon": 25,
                               "p_good": 0.4}))
    run("lyapunov_greedy",
        ControllerSpec("lyapunov", {"budget": 400.0, "horizon": ROUNDS}))
    for a in (1, 3, 5, 10):
        run(f"fixed_{a}", ControllerSpec("fixed", {"a": a}))


if __name__ == "__main__":
    main()
