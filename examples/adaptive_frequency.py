"""Adaptive aggregation frequency (paper Figs 4/5/8): compare the
DQN+Lyapunov agent against fixed frequencies under a resource budget in a
time-varying channel.

    PYTHONPATH=src python examples/adaptive_frequency.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core import envs


def rollout(policy, p, key, episodes=3):
    """policy(obs, key) -> action. Returns (mean final loss, mean energy)."""
    step_env = jax.jit(envs.step, static_argnums=2)
    losses, energy = [], []
    for ep in range(episodes):
        s, obs = envs.reset(jax.random.fold_in(key, ep), p)
        done, e = False, 0.0
        while not done:
            key, ka = jax.random.split(key)
            a = policy(obs, ka)
            s, obs, r, done, info = step_env(s, a, p)
            e += float(info["consumed"])
        losses.append(float(s.loss))
        energy.append(e)
    return np.mean(losses), np.mean(energy)


def main():
    key = jax.random.PRNGKey(0)
    p = envs.EnvParams(horizon=40, p_good=0.4)

    # train the agent (Algorithm 1)
    dcfg = core.DQNConfig(buffer_size=1024, batch_size=32, lr=2e-3)
    agent = core.init_dqn(key, dcfg)
    step_env = jax.jit(envs.step, static_argnums=2)
    for ep in range(8):
        s, obs = envs.reset(jax.random.fold_in(key, ep), p)
        done = False
        while not done:
            key, ka, kt = jax.random.split(key, 3)
            a = core.select_action(ka, agent, dcfg, obs)
            s, obs2, r, done, _ = step_env(s, a, p)
            agent = core.store(agent, obs, a, r, obs2)
            agent, _ = core.dqn_train_step(kt, agent, dcfg)
            obs = obs2

    print("policy,final_loss,energy")
    loss, e = rollout(
        lambda obs, k: jnp.argmax(core.q_values(agent.eval_params, obs)),
        p, jax.random.PRNGKey(7))
    print(f"dqn_adaptive,{loss:.4f},{e:.2f}")
    for a_fixed in [1, 3, 5, 10]:
        loss, e = rollout(lambda obs, k, a=a_fixed: jnp.int32(a - 1),
                          p, jax.random.PRNGKey(7))
        print(f"fixed_{a_fixed},{loss:.4f},{e:.2f}")


if __name__ == "__main__":
    main()
