"""Secure aggregation demo: trust weighting vs robust baselines under a
Byzantine label-flipping attack, with optional client-level DP.

    PYTHONPATH=src python examples/secure_aggregation.py
"""
import jax

import repro.core as core
from repro.data import dirichlet_partition, make_classification


def main():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=3072, dim=784)
    parts = dirichlet_partition(key, data.y, 8)

    print("aggregator,malicious,dp,final_acc")
    for agg in ("fedavg", "trust", "median", "multi_krum"):
        for dp in (0.0, 0.05):
            cfg = core.AsyncFLConfig(
                n_devices=8, n_clusters=2, local_batch=64, sim_seconds=10.0,
                malicious_frac=0.25, aggregator=agg,
                dp_clip=5.0 if dp else 0.0, dp_noise=dp, seed=3)
            tr = core.AsyncFederation(cfg, data, parts).run(eval_every=5.0)
            print(f"{agg},0.25,{dp},{tr.accs[-1]:.3f}")


if __name__ == "__main__":
    main()
