"""End-to-end driver: federated training of a reduced assigned architecture
(~100M-scale possible via flags) for a few hundred steps with the full FL
control plane, then serve it with batched decode requests.

    PYTHONPATH=src python examples/federated_lm.py --arch gemma-2b --steps 200
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    print(f"== federated training ({args.arch}, {args.steps} steps) ==")
    subprocess.run([sys.executable, "-m", "repro.launch.train",
                    "--arch", args.arch, "--steps", str(args.steps),
                    "--clients", "4", "--clusters", "2"], check=True)
    print("== serving (prefill + batched decode) ==")
    subprocess.run([sys.executable, "-m", "repro.launch.serve",
                    "--arch", args.arch, "--batch", "4",
                    "--prompt-len", "32", "--gen", "32"], check=True)


if __name__ == "__main__":
    main()
