"""Quickstart: the paper's full pipeline end to end.

Digital twins of a heterogeneous device fleet -> K-means clustering ->
DQN aggregation-frequency agent trained on the DT-simulated environment ->
asynchronous clustered federated learning with trust-weighted aggregation
on a synthetic MNIST-shaped task.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.core as core
from repro.core import envs
from repro.data import dirichlet_partition, make_classification


def main():
    key = jax.random.PRNGKey(0)

    # 1. federated data: 16 devices with non-IID (Dirichlet) class skew
    data = make_classification(key, n=4096, dim=784)
    parts = dirichlet_partition(key, data.y, 16, alpha=0.5)
    print(f"devices: 16, shards: {[len(p) for p in parts]}")

    # 2. train the DQN frequency agent on the DT-simulated environment
    #    (paper §IV-C: the agent interacts with the twins, not the devices)
    p = envs.EnvParams(horizon=30)
    dcfg = core.DQNConfig(buffer_size=512, batch_size=32, lr=2e-3)
    agent = core.init_dqn(key, dcfg)
    step_env = jax.jit(envs.step, static_argnums=2)
    for ep in range(4):
        s, obs = envs.reset(jax.random.fold_in(key, ep), p)
        done, tot = False, 0.0
        while not done:
            key, ka, kt = jax.random.split(key, 3)
            a = core.select_action(ka, agent, dcfg, obs)
            s, obs2, r, done, _ = step_env(s, a, p)
            agent = core.store(agent, obs, a, r, obs2)
            agent, _ = core.dqn_train_step(kt, agent, dcfg)
            obs, tot = obs2, tot + float(r)
        print(f"dqn episode {ep}: return {tot:.2f}")

    # 3. asynchronous clustered FL with trust-weighted aggregation
    cfg = core.AsyncFLConfig(n_devices=16, n_clusters=4, local_batch=64,
                             sim_seconds=20.0, malicious_frac=0.125)
    fed = core.AsyncFederation(cfg, data, parts, agent=agent, dqn_cfg=dcfg)
    trace = fed.run(eval_every=2.0)
    for t, a in zip(trace.times, trace.accs):
        print(f"t={t:5.1f}s  acc={a:.3f}")
    print(f"aggregations: {fed.agg_count}, energy: {fed.energy_used:.1f}")
    rep = jax.device_get(fed.rep)
    print("reputation (malicious flagged *):")
    for i, r in enumerate(rep):
        print(f"  device {i:2d}: {r:7.2f}{' *' if fed.malicious[i] else ''}")


if __name__ == "__main__":
    main()
