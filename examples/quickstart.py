"""Quickstart: the paper's full pipeline through the unified API.

One declarative `FederationSpec` drives everything: digital twins of a
heterogeneous device fleet -> K-means clustering -> DQN aggregation-frequency
agent (trained on the DT-simulated environment, §IV-C: the agent interacts
with the twins, not the devices) -> asynchronous clustered federated learning
with trust-weighted aggregation (Pallas kernel hot path) on a synthetic
MNIST-shaped task.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import (ClusteringSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec)


def main():
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=16, malicious_frac=0.125),
        clustering=ClusteringSpec(n_clusters=4),
        # the registry factory pretrains an Alg.-1 DQN on the DT env
        controller=ControllerSpec("dqn", {"episodes": 4, "horizon": 30,
                                          "seed": 0}),
        sim_seconds=20.0,
        local_batch=64,
        seed=0,
    )
    print("spec:", {k: v for k, v in spec.to_dict().items()
                    if k in ("scale", "sim_seconds", "seed")})

    fed = Federation.from_spec(spec)       # synthetic non-IID data built in
    trace = fed.run(eval_every=2.0)

    for r in trace.records:
        print(f"t={r.t:5.1f}s  round={r.round:3d}  a={r.a}  "
              f"acc={r.acc:.3f}  loss={r.loss:.3f}")
    print(f"aggregations: {fed.agg_count}, energy: {fed.energy_used:.1f}")

    rep = jax.device_get(fed.rep)
    print("reputation (malicious flagged *):")
    for i, r in enumerate(rep):
        print(f"  device {i:2d}: {r:7.2f}{' *' if fed.malicious[i] else ''}")


if __name__ == "__main__":
    main()
