"""Straggler elimination via clustering (paper Figs 6/7): sweep the number
of K-means clusters and report accuracy-vs-simulated-time.

    PYTHONPATH=src python examples/async_clusters.py
"""
import jax

import repro.core as core
from repro.data import dirichlet_partition, make_classification


def main():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=4096, dim=784)
    parts = dirichlet_partition(key, data.y, 16)

    print("clusters,final_acc,aggregations,energy")
    for k in [1, 2, 4, 8]:
        cfg = core.AsyncFLConfig(n_devices=16, n_clusters=k, local_batch=64,
                                 sim_seconds=15.0)
        fed = core.AsyncFederation(cfg, data, parts)
        tr = fed.run(eval_every=3.0)
        print(f"{k},{tr.accs[-1]:.3f},{fed.agg_count},{fed.energy_used:.1f}")


if __name__ == "__main__":
    main()
