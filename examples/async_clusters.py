"""Straggler elimination via clustering (paper Figs 6/7): sweep the number
of K-means clusters through the unified API and report accuracy vs
simulated time.  The spec is data — the sweep is four dataclass replaces.

    PYTHONPATH=src python examples/async_clusters.py
"""
import dataclasses

from repro.api import (ControllerSpec, Federation, FederationSpec,
                       FleetSpec)


def main():
    base = FederationSpec(
        fleet=FleetSpec(n_devices=16),
        controller=ControllerSpec("fixed", {"a": 5}),
        sim_seconds=15.0,
        local_batch=64,
        seed=0,
    )

    print("clusters,final_acc,aggregations,energy")
    for k in [1, 2, 4, 8]:
        spec = base.replace(clustering=dataclasses.replace(
            base.clustering, n_clusters=k))
        fed = Federation.from_spec(spec)
        tr = fed.run(eval_every=3.0)
        print(f"{k},{tr.accs[-1]:.3f},{fed.agg_count},{fed.energy_used:.1f}")


if __name__ == "__main__":
    main()
