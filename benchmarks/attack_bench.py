"""Robustness bench: (fault mode x aggregator) grid -> BENCH_robustness.json.

The paper claims trust-weighted aggregation (Eqns 4-6) "effectively
resists malicious attacks".  This bench injects declarative faults
(`FederationSpec.faults`) *inside* the jitted round and measures the final
metric with and without trust, on both workloads:

* ``mlp``                  non-IID classification; metric = accuracy
* ``autoencoder-anomaly``  reconstruction anomaly detection; metric = AUC
  (labels never enter the loss, so ``label_flip``-style attacks are
  no-ops — ``poison`` corrupts the *inputs*, the only attack surface)

Fault modes: ``clean`` (control), ``sign_flip`` / ``gaussian`` Byzantine
update corruption, and ``poison`` (additive input noise on a static
device subset).  Aggregators: ``trust`` vs ``fedavg`` — the grid's delta
column is the trust recovery the acceptance gate checks.

    PYTHONPATH=src python benchmarks/attack_bench.py [--fast] [--out F]

Prints ``attack,<workload>/<fault>/<agg>,<metric>`` rows and writes the
grid + per-fault recovery summary to BENCH_robustness.json.
"""
from __future__ import annotations

import dataclasses
import json
import sys

# per-workload fault strengths: attacks are meaningful only relative to a
# workload's own gradient scale and fragility (the autoencoder diverges
# under magnitudes the classifier shrugs off), so each workload gets the
# strongest settings its training still survives *with* trust
FAULTS = {
    "mlp": {
        "clean":     {},
        "sign_flip": {"corrupt_mode": "sign_flip", "corrupt_frac": 0.25,
                      "corrupt_scale": 4.0},
        "gaussian":  {"corrupt_mode": "gaussian", "corrupt_frac": 0.25,
                      "corrupt_scale": 8.0},
        "poison":    {"poison_frac": 0.375, "poison_scale": 8.0},
    },
    "autoencoder-anomaly": {
        "clean":     {},
        "sign_flip": {"corrupt_mode": "sign_flip", "corrupt_frac": 0.25,
                      "corrupt_scale": 3.0},
        "gaussian":  {"corrupt_mode": "gaussian", "corrupt_frac": 0.25,
                      "corrupt_scale": 8.0},
        "poison":    {"poison_frac": 0.375, "poison_scale": 4.0},
    },
}
AGGREGATORS = ("trust", "fedavg")


def _specs(fast: bool):
    from repro.api import (AggregatorSpec, ClusteringSpec, ControllerSpec,
                           FederationSpec, FleetSpec, TaskSpec)
    mlp = FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 5}),
        aggregator=AggregatorSpec("trust"),
        execution="scanned", rounds=12 if fast else 40, sim_seconds=1e9,
        seed=11)
    ae = FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 2048, "dim": 32,
                       "n_types": 8, "hidden": 64, "code": 8}),
        execution="scanned", rounds=16, sim_seconds=1e9,
        local_batch=32, lr=0.1, seed=11)
    return {"mlp": mlp, "autoencoder-anomaly": ae}


def run(fast: bool = False, out_path: str = "BENCH_robustness.json"):
    from repro.api import Federation
    from repro.faults import FaultSpec

    grid = []
    for workload, base in _specs(fast).items():
        for fault, fkw in FAULTS[workload].items():
            for agg in AGGREGATORS:
                spec = dataclasses.replace(
                    base,
                    aggregator=dataclasses.replace(base.aggregator,
                                                   kind=agg),
                    faults=FaultSpec(**fkw))
                tr = Federation.from_spec(spec).run_scanned(spec.rounds)
                rec = tr.records[-1]
                row = {"workload": workload, "fault": fault,
                       "aggregator": agg, "rounds": spec.rounds,
                       "final_metric": float(rec.acc),
                       "final_loss": float(rec.loss)}
                grid.append(row)
                print(f"attack,{workload}/{fault}/{agg},{rec.acc:.4f}")

    by = {(r["workload"], r["fault"], r["aggregator"]): r["final_metric"]
          for r in grid}
    recovery = [
        {"workload": w, "fault": f,
         "trust": by[(w, f, "trust")], "fedavg": by[(w, f, "fedavg")],
         "trust_recovery": round(by[(w, f, "trust")]
                                 - by[(w, f, "fedavg")], 4)}
        for w in ("mlp", "autoencoder-anomaly")
        for f in FAULTS[w] if f != "clean"]
    out = {"bench": "robustness", "fast": fast, "grid": grid,
           "recovery": recovery}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in recovery:
        print(f"attack,recovery/{r['workload']}/{r['fault']},"
              f"{r['trust_recovery']:+.4f}")
    print(f"wrote {out_path}")
    return out


def main():
    run(fast="--fast" in sys.argv,
        out_path=next((a.split("=", 1)[1] for a in sys.argv
                       if a.startswith("--out=")),
                      "BENCH_robustness.json"))


if __name__ == "__main__":
    main()
