"""Byzantine-attack benchmark: the paper's trust-weighted aggregation vs
plain FedAvg and the standard robust rules, under label-flipping attackers
(paper claim: trust aggregation "effectively resists malicious attacks").

Prints ``attack,<aggregator>_mal<frac>,final_acc`` rows.
"""
from __future__ import annotations

import jax

import repro.core as core
from .common import fed_setup


def run(sim_seconds=8.0):
    out = {}
    for mal in (0.0, 0.25):
        data, parts = fed_setup(n_devices=8, n=2048, dim=96, seed=11)
        for agg in ("fedavg", "trust", "median", "multi_krum",
                    "trimmed_mean"):
            cfg = core.AsyncFLConfig(
                n_devices=8, n_clusters=2, local_batch=48,
                sim_seconds=sim_seconds, malicious_frac=mal,
                aggregator=agg, seed=11)
            tr = core.AsyncFederation(cfg, data, parts).run(eval_every=2.0)
            out[(agg, mal)] = tr.accs[-1]
            print(f"attack,{agg}_mal{mal},{tr.accs[-1]:.4f}")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
