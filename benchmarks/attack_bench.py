"""Robustness bench: (fault mode x aggregator) grid -> BENCH_robustness.json.

The paper claims trust-weighted aggregation (Eqns 4-6) "effectively
resists malicious attacks".  This bench injects declarative faults
(`FederationSpec.faults`) *inside* the jitted round and measures the final
metric with and without trust, on both workloads:

* ``mlp``                  non-IID classification; metric = accuracy
* ``autoencoder-anomaly``  reconstruction anomaly detection; metric = AUC
  (labels never enter the loss, so ``label_flip``-style attacks are
  no-ops — ``poison`` corrupts the *inputs*, the only attack surface)

Fault modes: ``clean`` (control), ``sign_flip`` / ``gaussian`` Byzantine
update corruption, and ``poison`` (additive input noise on a static
device subset).  Aggregators: ``trust`` vs ``fedavg`` — the grid's delta
column is the trust recovery the acceptance gate checks.  A cell whose
training diverges to NaN (fedavg frequently does under the strongest
attacks — that is the result) scores 0.0 with ``diverged: true``.

The trust/fedavg cells of each fault mode are structurally identical, so
they run as one B=2 `repro.pop.PopulationEngine` program (the aggregator
flag is a lifted per-member scalar); the sequential per-spec runs are
kept as the timing baseline and bit-parity check, and the per-cell
wall-clock delta lands in the output's ``timing`` table.

    PYTHONPATH=src python benchmarks/attack_bench.py [--fast] [--out F]

Prints ``attack,<workload>/<fault>/<agg>,<metric>`` rows and writes the
grid + per-fault recovery summary to BENCH_robustness.json.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time

# per-workload fault strengths: attacks are meaningful only relative to a
# workload's own gradient scale and fragility (the autoencoder diverges
# under magnitudes the classifier shrugs off), so each workload gets the
# strongest settings its training still survives *with* trust
FAULTS = {
    "mlp": {
        "clean":     {},
        "sign_flip": {"corrupt_mode": "sign_flip", "corrupt_frac": 0.25,
                      "corrupt_scale": 4.0},
        "gaussian":  {"corrupt_mode": "gaussian", "corrupt_frac": 0.25,
                      "corrupt_scale": 8.0},
        "poison":    {"poison_frac": 0.375, "poison_scale": 8.0},
    },
    "autoencoder-anomaly": {
        "clean":     {},
        "sign_flip": {"corrupt_mode": "sign_flip", "corrupt_frac": 0.25,
                      "corrupt_scale": 3.0},
        "gaussian":  {"corrupt_mode": "gaussian", "corrupt_frac": 0.25,
                      "corrupt_scale": 8.0},
        "poison":    {"poison_frac": 0.375, "poison_scale": 4.0},
    },
}
AGGREGATORS = ("trust", "fedavg")


def _same(a, b):
    # bitwise trace parity modulo NaN: a diverged member NaNs at the same
    # round in both arms, and NaN != NaN would mask that agreement
    return a == b or (a != a and b != b)


def _specs(fast: bool):
    from repro.api import (AggregatorSpec, ClusteringSpec, ControllerSpec,
                           FederationSpec, FleetSpec, TaskSpec)
    mlp = FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 5}),
        aggregator=AggregatorSpec("trust"),
        execution="scanned", rounds=12 if fast else 40, sim_seconds=1e9,
        seed=11)
    ae = FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 2048, "dim": 32,
                       "n_types": 8, "hidden": 64, "code": 8}),
        execution="scanned", rounds=16, sim_seconds=1e9,
        local_batch=32, lr=0.1, seed=11)
    return {"mlp": mlp, "autoencoder-anomaly": ae}


def run(fast: bool = False, out_path: str = "BENCH_robustness.json"):
    from repro.api import Federation
    from repro.faults import FaultSpec
    from repro.pop import PopulationEngine

    grid = []
    timing = []
    for workload, base in _specs(fast).items():
        for fault, fkw in FAULTS[workload].items():
            # the trust/fedavg cells of one fault mode are structurally
            # identical (the aggregator flag is a lifted scalar), so the
            # population engine runs the whole cell as ONE vmapped
            # program — one compile instead of one per aggregator
            specs = [dataclasses.replace(
                base,
                aggregator=dataclasses.replace(base.aggregator, kind=agg),
                faults=FaultSpec(**fkw)) for agg in AGGREGATORS]
            t0 = time.perf_counter()
            traces = PopulationEngine(specs).run_scanned(base.rounds)
            t_pop = time.perf_counter() - t0
            t0 = time.perf_counter()
            refs = [Federation.from_spec(s).run_scanned(s.rounds)
                    for s in specs]
            t_seq = time.perf_counter() - t0
            timing.append({"workload": workload, "fault": fault,
                           "members": len(specs),
                           "population_s": round(t_pop, 3),
                           "sequential_s": round(t_seq, 3),
                           "wall_clock_delta_s": round(t_seq - t_pop, 3),
                           "speedup": round(t_seq / max(t_pop, 1e-9), 2)})
            for agg, tr, ref in zip(AGGREGATORS, traces, refs):
                rec, rref = tr.records[-1], ref.records[-1]
                assert _same(rec.loss, rref.loss) and \
                    _same(rec.acc, rref.acc), \
                    f"population/{workload}/{fault}/{agg} diverged from " \
                    "the sequential reference"
                loss_f, acc_f = float(rec.loss), float(rec.acc)
                diverged = acc_f != acc_f or loss_f != loss_f
                row = {"workload": workload, "fault": fault,
                       "aggregator": agg, "rounds": base.rounds,
                       "final_metric": 0.0 if acc_f != acc_f else acc_f,
                       "final_loss": None if loss_f != loss_f else loss_f,
                       "diverged": diverged}
                grid.append(row)
                print(f"attack,{workload}/{fault}/{agg},"
                      f"{row['final_metric']:.4f}"
                      f"{' (diverged)' if diverged else ''}")

    by = {(r["workload"], r["fault"], r["aggregator"]): r["final_metric"]
          for r in grid}
    recovery = [
        {"workload": w, "fault": f,
         "trust": by[(w, f, "trust")], "fedavg": by[(w, f, "fedavg")],
         "trust_recovery": round(by[(w, f, "trust")]
                                 - by[(w, f, "fedavg")], 4)}
        for w in ("mlp", "autoencoder-anomaly")
        for f in FAULTS[w] if f != "clean"]
    out = {"bench": "robustness", "fast": fast, "grid": grid,
           "recovery": recovery, "timing": timing,
           "wall_clock_delta_s": round(sum(t["wall_clock_delta_s"]
                                           for t in timing), 3)}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in recovery:
        print(f"attack,recovery/{r['workload']}/{r['fault']},"
              f"{r['trust_recovery']:+.4f}")
    for t in timing:
        print(f"attack,walltime/{t['workload']}/{t['fault']},"
              f"{t['population_s']:.2f}s vs {t['sequential_s']:.2f}s "
              f"seq ({t['speedup']}x)")
    print(f"wrote {out_path}")
    return out


def main():
    run(fast="--fast" in sys.argv,
        out_path=next((a.split("=", 1)[1] for a in sys.argv
                       if a.startswith("--out=")),
                      "BENCH_robustness.json"))


if __name__ == "__main__":
    main()
