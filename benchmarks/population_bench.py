"""Population-engine throughput: B federations, one program vs B programs.

The sweep cost model the population engine attacks: a B-member sweep run
sequentially pays B engine builds, B scan compiles, and B dispatch
streams; `repro.pop.PopulationEngine` pays one (vmapped) build + compile
and runs all members in a single device program.  The curve sweeps
B = 1 -> 64 seed replicates of one small federation and records, per B:

* ``sequential_s``   sum of standalone ``Federation.from_spec(spec_b)
                     .run_scanned(K)`` wall-clocks (build + compile + run
                     per member — what a naive sweep costs)
* ``population_s``   `PopulationEngine(specs)` build + ``run_scanned(K)``
                     wall-clock (the same work, one program)
* ``steady_s``       a second ``run_scanned(K)`` with the compiled
                     program cached — the long-sweep marginal cost
* ``speedup``        sequential_s / population_s

The acceptance gate (printed + recorded): >= 4x speedup at B >= 16 on
one CPU host.

    PYTHONPATH=src python benchmarks/population_bench.py [--fast] [--out=F]

Writes BENCH_population.json next to the repo root.
"""
from __future__ import annotations

import json
import sys
import time


def _base_spec(seed=29):
    from repro.api import (AggregatorSpec, ClusteringSpec, ControllerSpec,
                           FederationSpec, FleetSpec, TaskSpec)
    return FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("mlp", {"n_samples": 256, "dim": 16, "hidden": 16}),
        execution="scanned", rounds=8, sim_seconds=1e9,
        local_batch=16, seed=seed)


def run(fast: bool = False, out_path: str = "BENCH_population.json"):
    from repro.api import Federation
    from repro.pop import PopulationEngine, PopulationSpec

    K = 6 if fast else 8
    sizes = (1, 4, 16) if fast else (1, 4, 16, 64)
    base = _base_spec()
    # process warmup: one throwaway standalone run, so neither arm's
    # first timing absorbs backend init / common-subcomputation caches
    # (each later Federation/PopulationEngine still pays its own scan
    # compile — fresh engine objects never share a jit cache entry)
    Federation.from_spec(base).run_scanned(2)
    curve = []
    for B in sizes:
        specs = PopulationSpec(base=base, replicates=B).expand()

        t0 = time.perf_counter()
        pop = PopulationEngine(specs)
        traces = pop.run_scanned(K)
        t_pop = time.perf_counter() - t0
        t0 = time.perf_counter()
        pop.run_scanned(K)
        t_steady = time.perf_counter() - t0

        t0 = time.perf_counter()
        refs = [Federation.from_spec(s).run_scanned(K) for s in specs]
        t_seq = time.perf_counter() - t0

        # free bit-parity check on the first timed segment
        key = lambda r: (r.t, r.round, r.cluster, r.a, r.loss,  # noqa: E731
                         r.acc, r.energy, r.agg_count)
        for b, (tr, ref) in enumerate(zip(traces, refs)):
            assert [key(r) for r in tr.records] == \
                [key(r) for r in ref.records], \
                f"B={B} member {b} diverged from its standalone run"

        row = {"B": B, "rounds": K,
               "sequential_s": round(t_seq, 3),
               "population_s": round(t_pop, 3),
               "steady_s": round(t_steady, 3),
               "steady_member_rounds_per_sec":
                   round(B * K / max(t_steady, 1e-9), 1),
               "speedup": round(t_seq / max(t_pop, 1e-9), 2)}
        curve.append(row)
        print(f"population,B={B},{row['population_s']}s vs "
              f"{row['sequential_s']}s seq ({row['speedup']}x, steady "
              f"{row['steady_member_rounds_per_sec']} member-rounds/s)")

    gate_rows = [r for r in curve if r["B"] >= 16]
    gate = {"threshold": 4.0,
            "speedup_at_16plus": max((r["speedup"] for r in gate_rows),
                                     default=None),
            "pass": any(r["speedup"] >= 4.0 for r in gate_rows)}
    out = {"bench": "population", "fast": fast, "curve": curve,
           "gate": gate}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"population,gate,>=4x@B>=16: "
          f"{'PASS' if gate['pass'] else 'FAIL'} "
          f"({gate['speedup_at_16plus']}x)")
    print(f"wrote {out_path}")
    return out


def main():
    run(fast="--fast" in sys.argv,
        out_path=next((a.split("=", 1)[1] for a in sys.argv
                       if a.startswith("--out=")),
                      "BENCH_population.json"))


if __name__ == "__main__":
    main()
