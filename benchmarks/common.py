"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import jax
import numpy as np

import repro.core as core
from repro.core import envs
from repro.data import dirichlet_partition, make_classification


def timed(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out   # us/call


def train_dqn_agent(episodes=8, horizon=40, p_good=0.5, calibrate=True,
                    seed=0, track_loss=False):
    """Algorithm 1 on the DT-simulated environment."""
    p = envs.EnvParams(horizon=horizon, p_good=p_good, calibrate_dt=calibrate)
    dcfg = core.DQNConfig(buffer_size=1024, batch_size=32, lr=2e-3)
    agent = core.init_dqn(jax.random.PRNGKey(seed), dcfg)
    key = jax.random.PRNGKey(seed + 1)
    step_env = jax.jit(envs.step, static_argnums=2)
    losses, rewards, energies, agg_counts = [], [], [], []
    for ep in range(episodes):
        s, obs = envs.reset(jax.random.fold_in(key, ep), p)
        done, tot, e_tot, aggs = False, 0.0, 0.0, 0
        while not done:
            key, ka, kt = jax.random.split(key, 3)
            a = core.select_action(ka, agent, dcfg, obs)
            s, obs2, r, done, info = step_env(s, a, p)
            agent = core.store(agent, obs, a, r, obs2)
            agent, td = core.dqn_train_step(kt, agent, dcfg)
            losses.append(float(td))
            obs = obs2
            tot += float(r)
            e_tot += float(info["consumed"])
            aggs += 1
        rewards.append(tot)
        energies.append(e_tot)
        agg_counts.append(aggs)
    return dict(agent=agent, dcfg=dcfg, td_losses=losses, rewards=rewards,
                energies=energies, agg_counts=agg_counts, params=p)


def fed_setup(n_devices=16, n=4096, dim=784, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    parts = dirichlet_partition(key, data.y, n_devices)
    return data, parts
