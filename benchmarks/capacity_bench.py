"""Capacity-scaling curve of the cluster-major shard_map engine.

How far does the fleet simulation stretch on one host?  The curve sweeps
n_devices = 10^4 -> 10^6 (fixed members-per-cluster growth, k-means
bypassed with a round-robin assignment, O(1)-per-device data shards) and
records setup + steady-state rounds/sec of the scanned cluster-major
round.  A second arm brings the same engine up under `jax.distributed`:
two local processes, two forced-host CPU devices each, one global 4-way
mesh — and asserts the 2-process trace agrees with the single-process
unsharded reference (scheduling/counters exact, float reductions
allclose) before recording its throughput.

    PYTHONPATH=src python benchmarks/capacity_bench.py            # full
    PYTHONPATH=src python benchmarks/capacity_bench.py --fast     # CI smoke

Writes BENCH_capacity.json next to the repo root.
"""
import os
import sys

if "--dist-worker" in sys.argv:
    # worker rank: join the jax.distributed job BEFORE importing jax —
    # initialize_from_env appends the forced-host device flag to
    # XLA_FLAGS, which XLA reads once at backend init
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(
        __file__)), "..", "src"))
    from repro.launch.distributed import initialize_from_env
    _DIST_PID = initialize_from_env()

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, FederationSpec,
                       FleetSpec, ShardingSpec)
from repro.api import registry
from repro.api.engine import DeviceScaleEngine
from repro.data import make_classification
from repro.data.federated import uniform_cycle_partition

SAMPLES, DIM = 4096, 16


def _spec(n, C, mesh=(1,), seed=0, rounds=8):
    return FederationSpec(
        fleet=FleetSpec(n_devices=n),
        clustering=api.ClusteringSpec(n_clusters=C),
        controller=ControllerSpec("fixed", {"a": 2}),
        aggregator=AggregatorSpec("trust", {"use_kernel": False}),
        execution="scanned", rounds=rounds, sim_seconds=1e9,
        local_batch=4, seed=seed, sharding=ShardingSpec(mesh=mesh))


def _build(spec, assign=None):
    data = make_classification(jax.random.PRNGKey(spec.seed), n=SAMPLES,
                               dim=DIM)
    parts = uniform_cycle_partition(SAMPLES, spec.fleet.n_devices)
    ctl = registry.CONTROLLERS.get(spec.controller.kind)(
        spec.controller.params)
    agg = registry.AGGREGATORS.get(spec.aggregator.kind)(
        dict(spec.aggregator.params))
    task = registry.TASKS.get(spec.task.kind)(spec.task.params)
    return DeviceScaleEngine.from_spec(
        spec, data=data, parts=parts, controller=ctl, aggregator=agg,
        task=task, assign=assign)


def _rounds_per_sec(eng, K, reps=3):
    eng.set_trace_sink(None, retain=False)    # deferred host sync
    eng.run_scanned(K, eval_final=False)      # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.run_scanned(K, eval_final=False)
        eng.energy_used                       # flush: includes host sync
        best = min(best, time.perf_counter() - t0)
    return K / best


# --------------------------------------------------------------------- #
# arm 1: single-process capacity curve
# --------------------------------------------------------------------- #
def run_curve(sizes):
    rows = []
    for n, C in sizes:
        t0 = time.perf_counter()
        # k-means on 10^6 twins would dominate setup; the curve measures
        # the engine, so clusters are assigned round-robin
        eng = _build(_spec(n, C), assign=np.arange(n, dtype=np.int32) % C)
        setup = time.perf_counter() - t0
        K = 20 if n <= 10 ** 5 else 5
        rps = _rounds_per_sec(eng, K, reps=3 if n <= 10 ** 5 else 2)
        row = {"n_devices": n, "n_clusters": C,
               "members_per_cluster": n // C,
               "setup_seconds": round(setup, 2),
               "rounds_per_sec": round(rps, 2),
               "ms_per_round": round(1e3 / rps, 2)}
        rows.append(row)
        print(f"capacity,n={n},clusters={C},setup_s={setup:.2f},"
              f"rounds_per_sec={rps:.2f}")
        del eng
    return rows


# --------------------------------------------------------------------- #
# arm 2: 2-process jax.distributed bring-up + trace parity
# --------------------------------------------------------------------- #
DIST_N, DIST_C, DIST_MESH, DIST_ROUNDS = 64, 8, (4,), 8


def dist_worker():
    """One rank of the 2-process job (spawned by run_distributed)."""
    spec = _spec(DIST_N, DIST_C, mesh=DIST_MESH, seed=5,
                 rounds=DIST_ROUNDS)
    eng = _build(spec)
    tr = eng.run_scanned(DIST_ROUNDS, eval_final=False)
    rows = [[r.t, r.round, r.cluster, r.a, r.loss, r.energy, r.agg_count]
            for r in tr.records]
    t0 = time.perf_counter()
    eng.run_scanned(DIST_ROUNDS, eval_final=False)
    rps = DIST_ROUNDS / (time.perf_counter() - t0)
    print("DISTROWS" + json.dumps(
        {"pid": _DIST_PID, "global_devices": jax.device_count(),
         "local_devices": jax.local_device_count(),
         "rounds_per_sec": round(rps, 2), "rows": rows}), flush=True)
    return 0


def run_distributed():
    from repro.launch.distributed import spawn_local

    res = spawn_local([os.path.abspath(__file__), "--dist-worker"],
                      n_procs=2, local_devices=2)
    for i, r in enumerate(res):
        if r.returncode:
            raise RuntimeError(
                f"dist worker {i} failed:\n{r.stderr[-3000:]}")
    payloads = [json.loads(r.stdout.split("DISTROWS", 1)[1])
                for r in res]
    assert payloads[0]["rows"] == payloads[1]["rows"], \
        "worker processes emitted different traces"
    assert payloads[0]["global_devices"] == 4

    # single-process unsharded reference, same spec sans mesh
    ref_eng = _build(_spec(DIST_N, DIST_C, mesh=(), seed=5,
                           rounds=DIST_ROUNDS))
    ref = ref_eng.run_scanned(DIST_ROUNDS, eval_final=False)
    ref_rows = [[r.t, r.round, r.cluster, r.a, r.loss, r.energy,
                 r.agg_count] for r in ref.records]
    dist_rows = payloads[0]["rows"]
    assert len(ref_rows) == len(dist_rows) == DIST_ROUNDS
    for p, s in zip(ref_rows, dist_rows):
        assert p[1:4] == s[1:4] and p[6] == s[6], (p, s)
        np.testing.assert_allclose([p[0], p[4], p[5]],
                                   [s[0], s[4], s[5]],
                                   rtol=1e-5, atol=1e-6)
    print(f"capacity,distributed_2proc_rounds_per_sec,"
          f"{payloads[0]['rounds_per_sec']:.2f} (parity asserted over "
          f"{DIST_ROUNDS} rounds)")
    return {"n_processes": 2, "local_devices_per_process": 2,
            "mesh": list(DIST_MESH), "n_devices": DIST_N,
            "n_clusters": DIST_C, "rounds": DIST_ROUNDS,
            "rounds_per_sec": payloads[0]["rounds_per_sec"],
            "trace_parity": "round/cluster/a/agg_count exact vs the "
                            "single-process unsharded engine; t/loss/"
                            "energy allclose rtol=1e-5 atol=1e-6 "
                            "(the Eqn-19 psum reassociates the sum)"}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: curve stops at 10^4 devices")
    ap.add_argument("--skip-dist", action="store_true",
                    help="skip the 2-process jax.distributed arm")
    ap.add_argument("--dist-worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--out", default="BENCH_capacity.json")
    args = ap.parse_args(argv)

    if args.dist_worker:
        return dist_worker()

    sizes = [(10 ** 4, 64)]
    if not args.fast:
        sizes += [(10 ** 5, 512), (10 ** 6, 4096)]
    curve = run_curve(sizes)
    dist = None if args.skip_dist else run_distributed()

    if not args.fast:
        payload = {
            "bench": "cluster-major shard_map engine capacity: scanned "
                     "rounds/sec vs fleet size, plus a 2-process "
                     "jax.distributed bring-up with asserted trace parity",
            "note": "curve: 1-device mesh, round-robin cluster assignment "
                    "(k-means bypassed), O(1)-per-device cyclic data "
                    "shards, deferred host sync (no trace sink); "
                    "distributed: 2 processes x 2 forced-host CPU devices "
                    "= one 4-way mesh, gloo collectives",
            "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
            "device": str(jax.devices()[0]),
            "samples": SAMPLES, "dim": DIM, "local_batch": 4,
            "curve": curve,
            "distributed": dist,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
