#!/usr/bin/env bash
# Single CI entry point: tier-1 tests + headless example + scenario CLI.
#
#   bash benchmarks/smoke.sh          # full tier-1 suite + smoke drivers
#   bash benchmarks/smoke.sh --fast   # skip the pytest suite
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

if [[ "${1:-}" != "--fast" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

echo "== quickstart example (headless) =="
python examples/quickstart.py > /tmp/quickstart.out
tail -n 3 /tmp/quickstart.out

echo "== scenario CLI =="
python -m repro.api.run --scenario sync-baseline --sim-seconds 4 \
    --devices 8 --clusters 1 --eval-every 2
python -m repro.api.run --scenario byzantine --sim-seconds 4 \
    --devices 8 --clusters 2 --eval-every 2
python -m repro.api.run --scenario lm-modeA --rounds 2

echo "== engine throughput (fused FleetState round vs reference, fast) =="
python benchmarks/engine_bench.py --fast

echo "== scan-over-rounds (run_scanned vs event heap, fast) =="
python benchmarks/engine_bench.py --scanned --fast

echo "== scanned scenario CLI =="
python -m repro.api.run --scenario adaptive-scanned --rounds 6 \
    --devices 8 --clusters 2 | tail -n 3

echo "== service mode (start -> checkpoint -> resume -> status) =="
SERVE_DIR=$(mktemp -d /tmp/serve_smoke.XXXXXX)
python -m repro.serve start --run-dir "$SERVE_DIR" \
    --scenario autoencoder-anomaly --segment-rounds 5 --max-segments 2 \
    --foreground
python -m repro.serve checkpoint --run-dir "$SERVE_DIR"
python -m repro.serve resume --run-dir "$SERVE_DIR" \
    --segment-rounds 5 --max-segments 1 --foreground
python -m repro.serve status --run-dir "$SERVE_DIR" --tail 1 \
    | python -c "import json,sys; s=json.load(sys.stdin)['state']; \
print('serve:', s['status'], 'rounds', s['rounds'], 'acc', s['last_acc'])"

echo "== telemetry (serve metrics + status --watch --once) =="
python -m repro.serve metrics --run-dir "$SERVE_DIR" > /tmp/serve_metrics.prom
grep -E -m 6 "^(fl_|service_)" /tmp/serve_metrics.prom
python -c "
import sys
text = open('/tmp/serve_metrics.prom').read()
for name in ('fl_rounds_total', 'service_segments_total',
             'fl_checkpoints_total'):
    line = next((l for l in text.splitlines()
                 if l.startswith(name)), None)
    assert line is not None, f'{name} missing from serve metrics'
    assert float(line.split()[-1]) > 0, f'{name} is zero: {line}'
print('telemetry: counters non-empty OK')
"
python -m repro.serve status --run-dir "$SERVE_DIR" --watch --once \
    > /tmp/serve_watch.txt
head -n 12 /tmp/serve_watch.txt
cp "$SERVE_DIR/metrics.jsonl" /tmp/serve_metrics.jsonl   # CI artifact
rm -rf "$SERVE_DIR"

echo "== chaos harness (SIGKILL mid-segment, supervised recovery) =="
CHAOS_DIR=$(mktemp -d /tmp/serve_chaos.XXXXXX)
python -m repro.serve chaos --run-dir "$CHAOS_DIR" \
    --scenario autoencoder-anomaly --segment-rounds 3 --total-segments 3 \
    --kills 1 | python -c "import json,sys; s=json.load(sys.stdin); \
print('chaos:', s['segments'], 'segments,', s['rounds'], 'rounds,', \
s['kills'], 'kills,', s['restarts'], 'restarts')"
rm -rf "$CHAOS_DIR"

echo "== population engine (vmapped federation fleets, B=1..16 fast) =="
python benchmarks/population_bench.py --fast \
    --out=/tmp/bench_population.json | tail -n 5

echo "== pool supervisor (multi-tenant serve: start -> resume -> status) =="
POOL_DIR=$(mktemp -d /tmp/serve_pool.XXXXXX)
python -m repro.serve pool start --run-dir "$POOL_DIR" \
    --scenario autoencoder-anomaly --replicates 2 --segment-rounds 4 \
    --max-segments 1 --foreground
python -m repro.serve pool resume --run-dir "$POOL_DIR" \
    --segment-rounds 4 --max-segments 1 --foreground
python -m repro.serve pool status --run-dir "$POOL_DIR" \
    | python -c "import json,sys; s=json.load(sys.stdin); \
assert [m['checkpoint_step'] for m in s['members']] == [8, 8], s; \
print('pool:', s['state']['status'], 'rounds', s['state']['rounds'], \
'members', s['state']['members'])"
rm -rf "$POOL_DIR"

echo "== robustness grid (fault mode x aggregator, fast) =="
python benchmarks/attack_bench.py --fast --out=/tmp/bench_robustness.json \
    | tail -n 8

echo "== segmented checkpointed execution (serve overhead, fast) =="
python benchmarks/engine_bench.py --segmented --fast

echo "== sharded placement (8-way forced host mesh) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/engine_bench.py --sharded --fast
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m repro.api.run --scenario adaptive-scanned --rounds 6 \
    --devices 8 --clusters 2 --mesh 8 | tail -n 3

echo "== capacity curve + 2-process jax.distributed parity (fast) =="
python benchmarks/capacity_bench.py --fast

echo "smoke OK"
