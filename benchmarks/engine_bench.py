"""Device-scale engine throughput: fused `FleetState` rounds vs the
pre-refactor engine.

Three engines run the same federation (same spec shapes, fixed controller,
trust aggregation):

  legacy     a faithful reconstruction of the pre-refactor
             `DeviceScaleEngine._cluster_round`: per-member batch assembly
             in Python lists, `np.asarray`/`float()` device syncs every
             round, an unjitted trust pipeline, and the O(C^2)
             `_pick_frequency` recomputation — the host-bound baseline the
             FleetState refactor replaced.
  reference  the *new* round function executed eagerly (fused=False):
             fixed-shape padded math, per-op dispatch, per-round host
             syncs.  Isolates the jit-fusion gain from the data-layout
             gain.
  fused      one jit-compiled `_fleet_round` call per round; only the
             event heap, controller select and a 4-scalar metrics pull
             stay on the host (the post-refactor hot path).

Fused and reference share RNG streams and produce matching traces (see
tests/test_api.py::test_fused_round_parity_with_reference); legacy is the
old computation (different batch sampler), timed on the same workload.

``--scanned`` benches the control plane instead: the per-event fused path
(host event heap + controller `select` each round) against
`run_scanned(K)` (K rounds + in-jit controller + Eqn-12 queue in one
`lax.scan`), for the `fixed` and `dqn` controllers.  The scanned/dqn
number is the headline: it is the adaptive-frequency path with zero
per-round host syncs.

``--sharded`` benches the placement layer: the same `run_scanned(K)`
workload on the single-device fallback vs a `ShardingSpec(mesh=(M,))`
host mesh (default M=8; force a CPU device pool with
XLA_FLAGS=--xla_force_host_platform_device_count=M).  A 1-D mesh now
resolves to the cluster-major `shard_map` engine
(`repro.api.cluster_engine`): memberships are shard-local by layout and
the round's only collectives are two psums, so the recorded ratio is the
real cost/benefit of splitting one CPU into M shards — it superseded the
0.17x the GSPMD-inferred path recorded (all-gathers on every membership
gather; still measurable via ``ShardingSpec(impl='gspmd')``).

``--segmented`` benches service-mode execution (`repro.serve`): S
segments of `run_scanned(K)` each followed by a full resumable checkpoint
(`SegmentRunner`) against the same S*K rounds in one scan — the recorded
per-segment overhead is the price of bit-exact resumability.

    PYTHONPATH=src python benchmarks/engine_bench.py            # full
    PYTHONPATH=src python benchmarks/engine_bench.py --fast     # CI smoke
    PYTHONPATH=src python benchmarks/engine_bench.py --scanned  # scan bench
    PYTHONPATH=src python benchmarks/engine_bench.py --segmented
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/engine_bench.py --sharded

Full runs write BENCH_engine_throughput.json / BENCH_engine_scan.json /
BENCH_engine_shard.json / BENCH_engine_segmented.json at the repo root.
"""
from __future__ import annotations

import argparse
import heapq
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (AggregatorSpec, ClusteringSpec, ControllerSpec,
                       Federation, FederationSpec, FleetSpec, ShardingSpec,
                       WeightedAggregator)
from repro.api.engine import _flatten_params
from repro.core.clustering import (cluster_devices, ensure_nonempty,
                                   tolerance_bound)
from repro.core.energy import (channel_transition, comm_energy,
                               compute_energy, step_channel)
from repro.core.trust import (belief, gradient_diversity, learning_quality,
                              time_weighted_average, trust_weights,
                              update_reputation)
from repro.core.twin import (TwinState, calibrate, calibrated_freq,
                             init_twins, observe_round, sample_deviation)
from repro.data import dirichlet_partition, make_classification


class LegacyEngine:
    """Frozen copy of the pre-refactor `DeviceScaleEngine` hot loop
    (commit 59dc9de), kept verbatim-in-spirit as the benchmark baseline:
    host-bound Python per-member batch assembly, no fused round, per-round
    device syncs, O(C^2) frequency recomputation in `_pick_frequency`."""

    def __init__(self, spec, data, parts, *, controller, aggregator, task):
        self.spec = spec
        self.data = data
        self.parts = parts
        self.controller = controller
        self.aggregator = aggregator
        self.task = task
        key = jax.random.PRNGKey(spec.seed)
        (self.key, kt, kd, kc, kp, km) = jax.random.split(key, 6)
        self.twins = sample_deviation(
            kd, init_twins(kt, spec.fleet.n_devices), spec.fleet.dt_max_dev)
        sizes = jnp.asarray([len(p) for p in parts], jnp.float32)
        self.twins = self.twins._replace(data_size=sizes)
        assign, _ = cluster_devices(kc, self.twins,
                                    spec.clustering.n_clusters)
        self.assign = ensure_nonempty(np.asarray(assign),
                                      spec.clustering.n_clusters)
        self.global_params = task.init(kp, dim=data.x.shape[1])
        self.cluster_params = [self.global_params] * spec.clustering.n_clusters
        self.cluster_ts = np.zeros(spec.clustering.n_clusters)
        self.round = 0
        self.rep = jnp.ones((spec.fleet.n_devices,))
        self.channel = jnp.zeros((spec.fleet.n_devices,), jnp.int32)
        self.malicious = np.zeros(spec.fleet.n_devices, bool)
        self.energy_used = 0.0
        self.agg_count = 0

    def _cluster_freq(self, c):
        members = np.where(self.assign == c)[0]
        f = np.asarray(calibrated_freq(self.twins))[members]
        return float(f.min()) if len(members) else 1.0

    def _pick_frequency(self, c):
        spec = self.spec
        a = self.controller.select(None)        # fixed controller only
        # same a_req/f_max tolerance reference as the live engine so both
        # benchmark arms run the identical per-round workload
        t_ref = a / max(max(self._cluster_freq(cc), 1e-6)
                        for cc in range(spec.clustering.n_clusters))
        alpha = min(1.0, spec.clustering.alpha0 +
                    spec.clustering.alpha_growth * self.round)
        a = int(tolerance_bound(jnp.asarray(a), jnp.asarray(
            self._cluster_freq(c)), jnp.asarray(t_ref), alpha))
        return max(1, min(a, self.controller.n_actions))

    def _cluster_round(self, c, a, kround):
        spec = self.spec
        members = np.where(self.assign == c)[0]
        kb, ke, kc2 = jax.random.split(kround, 3)
        xs, ys = [], []
        for m in members:                       # Python batch assembly
            ix = self.parts[m]
            sel = np.asarray(jax.random.choice(
                jax.random.fold_in(kb, int(m)), jnp.asarray(ix),
                (spec.local_batch,), replace=len(ix) < spec.local_batch))
            xs.append(np.asarray(self.data.x)[sel])
            ys.append(np.asarray(self.data.y)[sel])
        batch = {"x": jnp.asarray(np.stack(xs)),
                 "y": jnp.asarray(np.stack(ys))}
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(members),) + x.shape),
            self.cluster_params[c])
        new = self.task.local_train(stacked, batch, spec.lr, a)
        upd_flat = _flatten_params(new) - _flatten_params(stacked)
        q = learning_quality(upd_flat)
        div = gradient_diversity(upd_flat)
        tw_m = jax.tree.map(lambda x: x[members], self.twins._asdict())
        b = belief(TwinState(**tw_m), q, spec.channel.pkt_fail, div)
        rep_m = update_reputation(self.rep[members], b,
                                  spec.channel.pkt_fail, spec.iota)
        self.rep = self.rep.at[jnp.asarray(members)].set(rep_m)
        w = trust_weights(rep_m)
        self.cluster_params[c] = self.aggregator(new, w)
        losses = self.task.losses(new, batch)
        e_cmp = a * compute_energy(
            (self.twins.freq + self.twins.freq_dev)[members])
        e_com = comm_energy(self.channel[members], ke)
        self.energy_used += float(e_cmp.sum() + e_com.sum())
        full_loss = self.twins.loss.at[jnp.asarray(members)].set(losses)
        full_e = jnp.zeros_like(self.twins.energy).at[
            jnp.asarray(members)].set(e_cmp + e_com)
        self.twins = observe_round(
            self.twins, full_loss, full_e,
            jnp.asarray(self.malicious, jnp.float32))
        if spec.fleet.calibrate_dt:
            self.twins = calibrate(self.twins)
        self.channel = step_channel(kc2, self.channel,
                                    channel_transition(spec.channel.p_good))
        return float(a) / max(self._cluster_freq(c), 1e-6)

    def run(self, eval_every=1.0, max_rounds=None):
        spec = self.spec
        events = [(0.0, c) for c in range(spec.clustering.n_clusters)]
        heapq.heapify(events)
        t, done = 0.0, 0
        while events and t < spec.sim_seconds:
            if max_rounds is not None and done >= max_rounds:
                break
            t, c = heapq.heappop(events)
            self.key, ka, kr = jax.random.split(self.key, 3)
            a = self._pick_frequency(c)
            dur = self._cluster_round(c, a, kr)
            self.round += 1
            self.cluster_ts[c] = self.round
            staleness = jnp.asarray(self.round - self.cluster_ts,
                                    jnp.float32)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *self.cluster_params)
            self.global_params, _ = time_weighted_average(stacked, staleness)
            self.agg_count += 1
            self.cluster_params[c] = self.global_params
            heapq.heappush(events, (t + dur, c))
            done += 1


def _build(n_devices, n_clusters, seed, fused, data, parts, local_batch):
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=n_devices),
        clustering=ClusteringSpec(n_clusters=n_clusters),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        sim_seconds=1e9,                 # bounded by max_rounds, not time
        local_batch=local_batch, seed=seed)
    return Federation.from_spec(spec, data=data, parts=parts, fused=fused)


def bench_mode(fused, *, n_devices, n_clusters, rounds, warmup, data,
               parts, local_batch=64, seed=0):
    fed = _build(n_devices, n_clusters, seed, fused, data, parts,
                 local_batch)
    fed.run(eval_every=1e9, max_rounds=warmup)        # compile + warm
    t0 = time.perf_counter()
    fed.run(eval_every=1e9, max_rounds=rounds)
    dt = time.perf_counter() - t0
    return rounds / dt, dt


def bench_fused_split(*, n_devices, n_clusters, rounds, data, parts,
                      local_batch=64, seed=0):
    """Span-derived compile vs steady-state split of the fused scanned
    path.  With an `EngineObs` attached, the first ``run_scanned(K)`` is
    a scan-cache miss, so the engine AOT-compiles under its
    ``span("compile")``; the second identical call is a cache hit whose
    fenced ``span("round")`` is pure execution.  Separating the two keeps
    the perf trajectory honest: a compile-time regression and a
    steady-state regression are different bugs."""
    from repro.obs import EngineObs
    fed = _build(n_devices, n_clusters, seed, True, data, parts,
                 local_batch)
    obs = EngineObs()
    fed.engine.set_obs(obs)
    fed.engine.run_scanned(rounds, eval_final=False)    # pays the compile
    fed.engine.run_scanned(rounds, eval_final=False)    # steady state
    compile_sp = obs.spans.last("compile")
    steady = obs.spans.last("round")
    split = {
        "compile_s": round(compile_sp.dur_s, 4) if compile_sp else None,
        "steady_segment_s": round(steady.dur_s, 4),
        "steady_rounds_per_sec": round(rounds / steady.dur_s, 2),
        "steady_dispatch_s": round(steady.attrs["dispatch_s"], 4)
        if "dispatch_s" in steady.attrs else None,
    }
    hlo_flops = obs.m_hlo_flops.total()
    if hlo_flops:
        split["hlo_flops"] = hlo_flops
        split["hlo_collective_ops"] = obs.m_hlo_coll.total()
    return split


def bench_legacy(*, n_devices, n_clusters, rounds, warmup, data, parts,
                 local_batch=64, seed=0):
    from repro.api.components import FixedController, MLPTask
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=n_devices),
        clustering=ClusteringSpec(n_clusters=n_clusters),
        controller=ControllerSpec("fixed", {"a": 3}),
        sim_seconds=1e9, local_batch=local_batch, seed=seed)
    eng = LegacyEngine(spec, data, parts,
                       controller=FixedController(3),
                       aggregator=WeightedAggregator(), task=MLPTask())
    eng.run(max_rounds=warmup)
    t0 = time.perf_counter()
    eng.run(max_rounds=rounds)
    dt = time.perf_counter() - t0
    return rounds / dt, dt


def _controller_for(kind, agent_and_cfg):
    from repro.api.components import DQNController, FixedController
    if kind == "fixed":
        return FixedController(3)
    return DQNController(*agent_and_cfg)


def bench_controller(kind, scanned, *, n_devices, n_clusters, rounds,
                     warmup, data, parts, local_batch=16,
                     agent_and_cfg=None, seed=0):
    """Rounds/sec of the per-event fused path vs run_scanned(K) under a
    given controller kind.  A fresh engine per mode; the DQN agent is
    trained once and shared so both modes run the same policy."""
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=n_devices),
        clustering=ClusteringSpec(n_clusters=n_clusters),
        controller=ControllerSpec("fixed", {"a": 3}),   # shape only;
        aggregator=AggregatorSpec("trust"),             # instance overrides
        sim_seconds=1e9, local_batch=local_batch, seed=seed)
    fed = Federation.from_spec(spec, data=data, parts=parts,
                               controller=_controller_for(kind,
                                                          agent_and_cfg))
    # best of `reps` timed repetitions: per-round work is a few ms, so a
    # background scheduling blip in a single pass dominates the mean
    reps = 3
    if scanned:
        fed.engine.run_scanned(rounds, eval_final=False)   # compile + warm
        dt = min(_timed(lambda: fed.engine.run_scanned(rounds,
                                                       eval_final=False))
                 for _ in range(reps))
    else:
        fed.run(eval_every=1e9, max_rounds=warmup)
        dt = min(_timed(lambda: fed.run(eval_every=1e9, max_rounds=rounds))
                 for _ in range(reps))
    return rounds / dt


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run_scan_bench(args):
    from repro.api.components import DQNController
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=args.samples, dim=args.dim)
    parts = dirichlet_partition(key, data.y, args.devices)
    ctl = DQNController.pretrain(seed=0, episodes=2, horizon=15)
    agent_and_cfg = (ctl.agent, ctl.cfg)
    kw = dict(n_devices=args.devices, n_clusters=args.clusters,
              rounds=args.rounds, warmup=args.warmup, data=data,
              parts=parts, local_batch=args.local_batch)

    results = {}
    for kind in ("fixed", "dqn"):
        heap = bench_controller(kind, False, agent_and_cfg=agent_and_cfg,
                                **kw)
        scan = bench_controller(kind, True, agent_and_cfg=agent_and_cfg,
                                **kw)
        results[kind] = {"event_heap_rounds_per_sec": round(heap, 2),
                         "scanned_rounds_per_sec": round(scan, 2),
                         "speedup": round(scan / heap, 2)}
        print(f"engine,{kind}_event_heap_rounds_per_sec,{heap:.2f}")
        print(f"engine,{kind}_scanned_rounds_per_sec,{scan:.2f}")
        print(f"engine,{kind}_scanned_speedup,{scan / heap:.2f}x")

    if not args.fast:
        payload = {
            "bench": "DeviceScaleEngine rounds/sec: lax.scan-over-rounds "
                     "(in-jit controller + Lyapunov queue) vs the "
                     "per-event fused path",
            "note": "event_heap = one jitted _fleet_round per heap event "
                    "with host-side controller select (ctx pull per round "
                    "for dqn); scanned = run_scanned(K): K rounds, "
                    "controller and Eqn-12 queue in one lax.scan, metrics "
                    "synced once at the end",
            "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
            "device": str(jax.devices()[0]),
            "n_devices": args.devices,
            "n_clusters": args.clusters,
            "rounds_measured": args.rounds,
            "local_batch": args.local_batch,
            "dim": args.dim,
            **{f"{k}_{f}": v for k, r in results.items()
               for f, v in r.items()},
        }
        with open(args.scan_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.scan_out}")
    return 0


def bench_placement(mesh, *, n_devices, n_clusters, rounds, data, parts,
                    local_batch=8, seed=0):
    """Rounds/sec of run_scanned(K) under a given placement (mesh shape;
    () = the single-device fallback)."""
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=n_devices),
        clustering=ClusteringSpec(n_clusters=n_clusters),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        execution="scanned", rounds=rounds, sim_seconds=1e9,
        local_batch=local_batch, seed=seed,
        sharding=ShardingSpec(mesh=mesh))
    fed = Federation.from_spec(spec, data=data, parts=parts)
    fed.engine.run_scanned(rounds, eval_final=False)     # compile + warm
    dt = min(_timed(lambda: fed.engine.run_scanned(rounds,
                                                   eval_final=False))
             for _ in range(3))
    return rounds / dt


def run_shard_bench(args):
    mesh = (args.mesh_size,)
    if jax.device_count() < args.mesh_size:
        print(f"error: --sharded needs {args.mesh_size} devices, backend "
              f"exposes {jax.device_count()}; run under XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.mesh_size}")
        return 2
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=args.samples, dim=args.dim)
    parts = dirichlet_partition(key, data.y, args.devices)
    kw = dict(n_devices=args.devices, n_clusters=args.clusters,
              rounds=args.rounds, data=data, parts=parts,
              local_batch=args.local_batch)

    single = bench_placement((), **kw)
    sharded = bench_placement(mesh, **kw)
    print(f"engine,single_device_rounds_per_sec,{single:.2f}")
    print(f"engine,sharded_mesh{args.mesh_size}_rounds_per_sec,"
          f"{sharded:.2f}")
    print(f"engine,sharded_vs_single_ratio,{sharded / single:.2f}x "
          f"(n_devices={args.devices}, mesh={mesh})")

    if not args.fast:
        payload = {
            "bench": "DeviceScaleEngine run_scanned rounds/sec: "
                     "ShardingSpec mesh placement vs the single-device "
                     "fallback",
            "note": "sharded = the cluster-major shard_map engine "
                    "(repro.api.cluster_engine): fleet re-indexed so "
                    "memberships are shard-local, explicit jax.shard_map "
                    "round with exactly two psums (Eqn-19 average + packed "
                    "scalar metrics), zero all-gathers (HLO-pinned by "
                    "tests/test_cluster_engine.py).  Supersedes the 0.17x "
                    "this file recorded for the GSPMD-inferred path, which "
                    "stays selectable via ShardingSpec(impl='gspmd'); see "
                    "BENCH_capacity.json for the n_devices=10^4..10^6 "
                    "capacity curve",
            "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
            "device": str(jax.devices()[0]),
            "device_count": jax.device_count(),
            "mesh": list(mesh),
            "n_devices": args.devices,
            "n_clusters": args.clusters,
            "rounds_measured": args.rounds,
            "local_batch": args.local_batch,
            "dim": args.dim,
            "single_device_rounds_per_sec": round(single, 2),
            "sharded_rounds_per_sec": round(sharded, 2),
            "sharded_vs_single_ratio": round(sharded / single, 2),
        }
        with open(args.shard_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.shard_out}")
    return 0


def run_segmented_bench(args):
    """Checkpoint overhead of service-mode execution: S segments of
    `run_scanned(K)` with a full resumable checkpoint after each
    (`repro.serve.SegmentRunner`) vs the same S*K rounds in one scan."""
    import tempfile

    from repro.serve import SegmentRunner

    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=args.samples, dim=args.dim)
    parts = dirichlet_partition(key, data.y, args.devices)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=args.devices),
        clustering=ClusteringSpec(n_clusters=args.clusters),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        execution="scanned", rounds=args.segment_rounds, sim_seconds=1e9,
        local_batch=args.local_batch, seed=0)
    K, S = args.segment_rounds, args.segments

    fed = Federation.from_spec(spec, data=data, parts=parts)
    fed.engine.run_scanned(S * K, eval_final=False)       # compile + warm
    straight_dt = min(_timed(lambda: fed.engine.run_scanned(
        S * K, eval_final=False)) for _ in range(3))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        fed2 = Federation.from_spec(spec, data=data, parts=parts)
        runner = SegmentRunner(fed2, ckpt_dir, segment_rounds=K, keep=2,
                               eval_final=False)
        runner.run_segment()                              # compile + warm

        def run_segments():
            for _ in range(S):
                runner.run_segment()

        seg_dt = min(_timed(run_segments) for _ in range(3))
        ckpt_dt = min(_timed(runner.checkpoint) for _ in range(3))

    # per-scan sync cost, isolated from checkpointing: S segments with the
    # default per-scan device_get + trace build vs the same S segments
    # with no sink and retention off, where run_scanned queues each
    # segment's consumed stack device-side and the host f64 tally is
    # rebuilt only at the final host-facing read (energy_used)
    fed3 = Federation.from_spec(spec, data=data, parts=parts)
    fed3.engine.run_scanned(K, eval_final=False)          # compile + warm

    def run_synced():
        for _ in range(S):
            fed3.engine.run_scanned(K, eval_final=False)

    synced_dt = min(_timed(run_synced) for _ in range(5))

    fed4 = Federation.from_spec(spec, data=data, parts=parts)
    fed4.engine.set_trace_sink(None, retain=False)
    fed4.engine.run_scanned(K, eval_final=False)          # compile + warm

    def run_deferred():
        for _ in range(S):
            fed4.engine.run_scanned(K, eval_final=False)
        fed4.engine.energy_used                 # one flush per S segments

    deferred_dt = min(_timed(run_deferred) for _ in range(5))

    straight_rps = S * K / straight_dt
    seg_rps = S * K / seg_dt
    synced_rps = S * K / synced_dt
    deferred_rps = S * K / deferred_dt
    overhead = (seg_dt - straight_dt) / S
    print(f"engine,straight_scan_rounds_per_sec,{straight_rps:.2f}")
    print(f"engine,segmented_rounds_per_sec,{seg_rps:.2f}")
    print(f"engine,synced_segments_rounds_per_sec,{synced_rps:.2f}")
    print(f"engine,deferred_sync_rounds_per_sec,{deferred_rps:.2f}")
    print(f"engine,checkpoint_seconds_per_segment,{ckpt_dt:.4f}")
    print(f"engine,segment_overhead_seconds,{overhead:.4f} "
          f"(K={K}, {S} segments)")
    print(f"engine,deferred_vs_synced_ratio,"
          f"{deferred_rps / synced_rps:.3f}x")

    if not args.fast:
        payload = {
            "bench": "repro.serve segmented execution: run_scanned(K) x S "
                     "with a full resumable checkpoint per segment vs one "
                     "run_scanned(S*K)",
            "note": "checkpoint = FleetState (typed PRNG key included) + "
                    "event times + policy carry to .npz, plus the JSON "
                    "manifest, both written atomically; overhead is the "
                    "service-mode price of bit-exact resumability per "
                    "segment",
            "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
            "device": str(jax.devices()[0]),
            "n_devices": args.devices,
            "n_clusters": args.clusters,
            "segment_rounds": K,
            "segments": S,
            "local_batch": args.local_batch,
            "dim": args.dim,
            "straight_scan_rounds_per_sec": round(straight_rps, 2),
            "segmented_rounds_per_sec": round(seg_rps, 2),
            "synced_segments_rounds_per_sec": round(synced_rps, 2),
            "deferred_sync_rounds_per_sec": round(deferred_rps, 2),
            "checkpoint_seconds_per_segment": round(ckpt_dt, 4),
            "segment_overhead_seconds": round(overhead, 4),
            "throughput_ratio": round(seg_rps / straight_rps, 3),
            "deferred_vs_synced_ratio": round(deferred_rps / synced_rps, 3),
            "deferred_note": "synced = S bare run_scanned(K) calls with "
                             "the default per-scan device_get + trace "
                             "build; deferred = the same S segments with "
                             "no sink and retention off — run_scanned "
                             "queues consumed stacks device-side and "
                             "flushes once at the first host-facing read "
                             "(energy_used / checkpoint)",
        }
        with open(args.seg_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.seg_out}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--clusters", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--samples", type=int, default=None)
    # 128 features keeps the per-round model compute in the regime the
    # refactor targets (high-frequency rounds over many small IIoT
    # devices); --dim 784 reproduces the paper's MNIST shape, where the
    # vmapped matmuls + the CPU interpret-mode Pallas kernel dominate both
    # engines and compress the ratio.  The --scanned mode defaults go
    # further down the same axis (dim 32, batch 8, 16 clusters): tiny
    # per-device models at a high round rate, where per-event dispatch and
    # controller syncs are the bottleneck the scan removes.
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--local-batch", type=int, default=None)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: small fleet, few rounds, no JSON")
    ap.add_argument("--scanned", action="store_true",
                    help="bench run_scanned(K) vs the per-event fused path "
                         "(fixed and dqn controllers)")
    ap.add_argument("--sharded", action="store_true",
                    help="bench run_scanned(K) on a ShardingSpec mesh vs "
                         "the single-device fallback (needs a device pool; "
                         "see module docstring)")
    ap.add_argument("--mesh-size", type=int, default=8)
    ap.add_argument("--segmented", action="store_true",
                    help="bench checkpointed segments (repro.serve "
                         "SegmentRunner) vs one straight run_scanned")
    ap.add_argument("--segment-rounds", type=int, default=25,
                    help="K rounds per segment (--segmented)")
    ap.add_argument("--segments", type=int, default=4,
                    help="segments per timed pass (--segmented)")
    ap.add_argument("--out", default="BENCH_engine_throughput.json")
    ap.add_argument("--scan-out", default="BENCH_engine_scan.json")
    ap.add_argument("--shard-out", default="BENCH_engine_shard.json")
    ap.add_argument("--seg-out", default="BENCH_engine_segmented.json")
    args = ap.parse_args(argv)
    # per-mode defaults (any explicit flag wins)
    scan_defaults = dict(devices=64, clusters=16, rounds=150, samples=2048,
                         dim=32, local_batch=8)
    shard_defaults = dict(devices=256, clusters=16, rounds=60, samples=4096,
                          dim=32, local_batch=8)
    seg_defaults = dict(devices=64, clusters=16, rounds=100, samples=2048,
                        dim=32, local_batch=8)
    full_defaults = dict(devices=64, clusters=8, rounds=100, samples=4096,
                         dim=128, local_batch=64)
    mode_defaults = (shard_defaults if args.sharded
                     else scan_defaults if args.scanned
                     else seg_defaults if args.segmented else full_defaults)
    for name, val in mode_defaults.items():
        if getattr(args, name) is None:
            setattr(args, name, val)
    if args.fast:
        args.devices, args.clusters = 16, 2
        args.rounds, args.warmup = 8, 3
        args.samples, args.dim = 1024, 64
        if args.sharded:
            args.devices, args.clusters = 32, 4
        if args.segmented:
            args.segment_rounds, args.segments = 4, 2
    if args.sharded:
        return run_shard_bench(args)
    if args.scanned:
        return run_scan_bench(args)
    if args.segmented:
        return run_segmented_bench(args)

    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=args.samples, dim=args.dim)
    parts = dirichlet_partition(key, data.y, args.devices)
    kw = dict(n_devices=args.devices, n_clusters=args.clusters,
              rounds=args.rounds, warmup=args.warmup, data=data,
              parts=parts, local_batch=args.local_batch)

    legacy_rps, _ = bench_legacy(**kw)
    print(f"engine,legacy_rounds_per_sec,{legacy_rps:.2f}")
    ref_rps, _ = bench_mode(False, **kw)
    print(f"engine,reference_rounds_per_sec,{ref_rps:.2f}")
    fused_rps, _ = bench_mode(True, **kw)
    print(f"engine,fused_rounds_per_sec,{fused_rps:.2f}")
    speedup = fused_rps / legacy_rps
    print(f"engine,fused_vs_legacy_speedup,{speedup:.2f}x "
          f"(n_devices={args.devices}, {args.rounds} rounds)")
    print(f"engine,fused_vs_reference_speedup,{fused_rps / ref_rps:.2f}x")
    split = bench_fused_split(
        n_devices=args.devices, n_clusters=args.clusters,
        rounds=args.rounds, data=data, parts=parts,
        local_batch=args.local_batch)
    print(f"engine,scan_compile_s,{split['compile_s']}")
    print(f"engine,scan_steady_rounds_per_sec,"
          f"{split['steady_rounds_per_sec']}")

    if not args.fast:
        payload = {
            "bench": "DeviceScaleEngine rounds/sec: fused FleetState jit "
                     "round vs the pre-refactor engine",
            "note": "legacy = reconstruction of the pre-refactor "
                    "DeviceScaleEngine (Python batch assembly, per-round "
                    "np/float syncs, unjitted trust pipeline, O(C^2) "
                    "_pick_frequency); reference = the new fixed-shape "
                    "round executed eagerly (trace-matches fused, see "
                    "test_fused_round_parity_with_reference); fused = one "
                    "jitted FleetState round per event",
            "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
            "device": str(jax.devices()[0]),
            "n_devices": args.devices,
            "n_clusters": args.clusters,
            "rounds_measured": args.rounds,
            "local_batch": args.local_batch,
            "dim": args.dim,
            "legacy_rounds_per_sec": round(legacy_rps, 2),
            "reference_rounds_per_sec": round(ref_rps, 2),
            "fused_rounds_per_sec": round(fused_rps, 2),
            "speedup_vs_legacy": round(speedup, 2),
            "speedup_vs_reference": round(fused_rps / ref_rps, 2),
            # span-derived split (repro.obs): scan-path compile time vs
            # steady-state execution, so the trajectory separates
            # compilation regressions from execution regressions
            "scan_span_split": split,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
