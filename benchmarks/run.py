"""Benchmark driver: one function per paper table/figure plus the roofline
table from the dry-run artifacts.  Prints ``name,metric,value`` CSV."""
from __future__ import annotations

import sys


def main() -> None:
    from . import attack_bench, figures, kernels_bench, roofline
    quick = "--quick" in sys.argv
    print("benchmark,metric,value")
    if quick:
        figures.fig2_dqn_convergence(episodes=2)
        figures.fig3_dt_deviation(sim_seconds=4.0)
    else:
        for fn in figures.ALL:
            fn()
        attack_bench.main()
    kernels_bench.main()
    roofline.main()


if __name__ == "__main__":
    main()
