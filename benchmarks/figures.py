"""Paper-figure reproductions (Figs 2-8) on the synthetic MNIST-shaped task.

Each ``fig*`` function prints ``name,metric,value`` CSV rows and returns a
dict; ``benchmarks.run`` drives them all.  Mapping to the paper:

  fig2  DQN convergence (TD loss vs training rounds)
  fig3  accuracy with DT-deviation calibration vs without
  fig4  aggregation count vs channel-state distribution
  fig5  energy consumed vs channel state over DQN training
  fig6  accuracy vs time for cluster counts (straggler elimination)
  fig7  time-to-accuracy vs cluster count
  fig8  adaptive (DQN) vs fixed aggregation frequency accuracy
"""
from __future__ import annotations

import dataclasses

import numpy as np

import repro.core as core
from .common import fed_setup, train_dqn_agent


def fig2_dqn_convergence(episodes=8):
    out = train_dqn_agent(episodes=episodes)
    l = np.asarray(out["td_losses"])
    k = max(1, len(l) // 20)
    smooth = np.convolve(l, np.ones(k) / k, mode="valid")
    early, late = float(smooth[:k].mean()), float(smooth[-k:].mean())
    print(f"fig2,td_loss_early,{early:.4f}")
    print(f"fig2,td_loss_late,{late:.4f}")
    print(f"fig2,converged,{int(late < early)}")
    return dict(early=early, late=late, losses=l.tolist()[:200])


def fig3_dt_deviation(sim_seconds=10.0):
    """Fig 3: the deviation bites through (a) the DQN's reward (the DT
    mis-estimates compute energy -> noisy TD targets) and (b) the trust
    weights (deviation-normalized belief, Eqn 4) with malicious clients."""
    accs = {}
    for label, calibrate in [("calibrated", True), ("with_deviation", False)]:
        out = train_dqn_agent(episodes=4, horizon=25, calibrate=calibrate,
                              seed=1)
        data, parts = fed_setup(n_devices=8, n=2048, dim=96, seed=1)
        cfg = core.AsyncFLConfig(n_devices=8, n_clusters=2, local_batch=48,
                                 sim_seconds=sim_seconds, calibrate_dt=calibrate,
                                 dt_max_dev=0.3, malicious_frac=0.25, seed=1)
        tr = core.AsyncFederation(cfg, data, parts, agent=out["agent"],
                                  dqn_cfg=out["dcfg"]).run(eval_every=2.0)
        accs[label] = tr.accs[-1]
        print(f"fig3,acc_{label},{tr.accs[-1]:.4f}")
    return accs


def _greedy_rollout(agent, dcfg, p, key, loss_target=0.35, max_steps=200):
    """Greedy policy until the loss target: returns (aggregations,
    mean chosen a_i, energy consumed).  No budget truncation, so the
    CHANNEL-driven differences are visible (paper Fig 4/5 protocol)."""
    import dataclasses as _dc
    import jax
    import jax.numpy as jnp
    import repro.core as core
    from repro.core import envs
    p = p._replace(budget=1e9, horizon=max_steps)
    step_env = jax.jit(envs.step, static_argnums=2)
    s, obs = envs.reset(key, p)
    steps, e_tot, a_sum = 0, 0.0, 0.0
    while float(s.loss) > loss_target and steps < max_steps:
        a = jnp.argmax(core.q_values(agent.eval_params, obs))
        s, obs, r, done, info = step_env(s, a, p)
        steps += 1
        a_sum += float(a) + 1
        e_tot += float(info["consumed"])
    return steps, a_sum / max(steps, 1), e_tot


def fig4_channel_adaptation(episodes=6):
    """Aggregations to target + chosen frequency vs channel distribution:
    in bad channels the agent picks more local steps per aggregation
    (larger a_i), so aggregation count falls as p_good -> 0 relative to
    its local-step budget (paper Fig 4 mechanism)."""
    import jax
    from repro.core import envs
    rows = {}
    for p_good in [0.0, 0.2, 0.5, 0.8, 1.0]:
        out = train_dqn_agent(episodes=episodes, p_good=p_good, horizon=30,
                              seed=2)
        p = envs.EnvParams(p_good=p_good)
        aggs, mean_a, _ = _greedy_rollout(out["agent"], out["dcfg"], p,
                                          jax.random.PRNGKey(42))
        rows[p_good] = (aggs, mean_a)
        print(f"fig4,aggs_to_target_pgood_{p_good},{aggs}")
        print(f"fig4,mean_a_pgood_{p_good},{mean_a:.2f}")
    return rows


def fig5_energy_by_channel(episodes=6):
    """Energy to reach the loss target: early-training agent vs trained
    agent, per channel state (paper Fig 5: energy decreases over DQN
    training and with channel quality)."""
    import jax
    from repro.core import envs
    rows = {}
    for label, p_good in [("good", 0.9), ("medium", 0.5), ("bad", 0.1)]:
        early = train_dqn_agent(episodes=1, p_good=p_good, horizon=30, seed=3)
        late = train_dqn_agent(episodes=episodes, p_good=p_good, horizon=30,
                               seed=3)
        p = envs.EnvParams(p_good=p_good)
        _, _, e_early = _greedy_rollout(early["agent"], early["dcfg"], p,
                                        jax.random.PRNGKey(7))
        _, _, e_late = _greedy_rollout(late["agent"], late["dcfg"], p,
                                       jax.random.PRNGKey(7))
        rows[label] = (e_early, e_late)
        print(f"fig5,energy_{label}_early,{e_early:.3f}")
        print(f"fig5,energy_{label}_trained,{e_late:.3f}")
    return rows


def fig6_fig7_clustering(sim_seconds=12.0, target_acc=0.8):
    data, parts = fed_setup(n_devices=16, n=3072, dim=96, seed=4)
    curves, tta = {}, {}
    for k in [1, 2, 4, 8]:
        cfg = core.AsyncFLConfig(n_devices=16, n_clusters=k, local_batch=48,
                                 sim_seconds=sim_seconds, seed=4)
        tr = core.AsyncFederation(cfg, data, parts).run(eval_every=1.5)
        curves[k] = (tr.times, tr.accs)
        reach = [t for t, a in zip(tr.times, tr.accs) if a >= target_acc]
        tta[k] = reach[0] if reach else float("inf")
        print(f"fig6,final_acc_k{k},{tr.accs[-1]:.4f}")
        print(f"fig7,time_to_{target_acc}_k{k},{tta[k]:.2f}")
    return dict(curves={k: v[1] for k, v in curves.items()}, tta=tta)


def fig8_adaptive_vs_fixed(sim_seconds=4.0):
    """Accuracy within a short simulated budget (before saturation) —
    mid-training acc is where frequency adaptation shows (paper Fig 8)."""
    data, parts = fed_setup(n_devices=8, n=3072, dim=784, seed=5)
    out = train_dqn_agent(episodes=4, horizon=25, seed=5)
    base = core.AsyncFLConfig(n_devices=8, n_clusters=2, local_batch=48,
                              sim_seconds=sim_seconds, seed=5)
    tr_a = core.AsyncFederation(base, data, parts, agent=out["agent"],
                                dqn_cfg=out["dcfg"]).run(eval_every=1.0)
    accs = {"adaptive": tr_a.accs[-1]}
    print(f"fig8,acc_adaptive,{tr_a.accs[-1]:.4f}")
    for f in [1, 5, 10]:
        cfg = dataclasses.replace(base, fixed_frequency=f)
        tr_f = core.AsyncFederation(cfg, data, parts).run(eval_every=1.0)
        accs[f"fixed_{f}"] = tr_f.accs[-1]
        print(f"fig8,acc_fixed_{f},{tr_f.accs[-1]:.4f}")
    return accs


ALL = [fig2_dqn_convergence, fig3_dt_deviation, fig4_channel_adaptation,
       fig5_energy_by_channel, fig6_fig7_clustering, fig8_adaptive_vs_fixed]
