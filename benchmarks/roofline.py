"""Roofline table from dry-run records (benchmarks/dryrun_results.jsonl).

Reads the JSONL emitted by ``python -m repro.launch.dryrun`` and prints the
§Roofline table: three terms (seconds), dominant bottleneck, MODEL_FLOPS
(6·N·D dense / 6·N_active·D MoE for train; 2·N·B per token for decode) and
the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import json
import sys

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.plans import SHAPES


def model_flops(arch: str, shape: str) -> float:
    """Global analytic model FLOPs for one step of (arch, shape)."""
    cfg = get_config(arch)
    n_active = cfg.param_count(active_only=True)
    spec = SHAPES[shape]
    if spec["kind"] == "train":
        tokens = spec["seq"] * spec["global_batch"]
        return 6.0 * n_active * tokens
    if spec["kind"] == "prefill":
        tokens = spec["seq"] * spec["global_batch"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec["global_batch"]


def load(paths):
    recs = {}
    for path in paths:
        try:
            with open(path) as f:
                for line in f:
                    r = json.loads(line)
                    recs[(r["arch"], r["shape"], r["mesh"])] = r
        except FileNotFoundError:
            pass
    return recs


def table(recs, mesh="16x16"):
    rows = []
    header = (f"{'arch':<18} {'shape':<12} {'t_comp':>9} {'t_mem':>9} "
              f"{'t_coll':>9} {'bound':<6} {'MF/HLO':>7} {'mem_GB':>7} status")
    print(header)
    print("-" * len(header))
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r["status"] != "ok":
            print(f"{a:<18} {s:<12} {'-':>9} {'-':>9} {'-':>9} {'-':<6} "
                  f"{'-':>7} {'-':>7} {r['status'][:40]}")
            continue
        tc, tm, tl = r["t_compute"], r["t_memory"], r["t_collective"]
        bound = max((tc, "comp"), (tm, "mem"), (tl, "coll"))[1]
        mf = model_flops(a, s) / r["chips"]           # per-device
        ratio = mf / max(r["hlo_flops_per_dev"], 1.0)
        mem_gb = r["bytes_per_device"]["total"] / 1e9
        rows.append((a, s, tc, tm, tl, bound, ratio, mem_gb))
        print(f"{a:<18} {s:<12} {tc:9.4f} {tm:9.4f} {tl:9.4f} {bound:<6} "
              f"{ratio:7.3f} {mem_gb:7.1f} ok")
    return rows


def main(paths=None):
    if paths is None:
        argv = [a for a in sys.argv[1:] if not a.startswith("-")
                and a.endswith(".jsonl")]
        paths = argv or ["benchmarks/dryrun_results.jsonl",
                         "benchmarks/dryrun_results_multipod.jsonl"]
    recs = load(paths)
    if not recs:
        print("roofline,no_dryrun_records,0")
        return
    for mesh in ("16x16", "2x16x16"):
        if any(m == mesh for (_, _, m) in recs):
            print(f"\n== mesh {mesh} ==")
            table(recs, mesh)


if __name__ == "__main__":
    main()
