"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall-time and,
more importantly on this CPU container, HBM-traffic *models* for the TPU
target (the numbers the §Perf analysis uses)."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref, trust_aggregate
from .common import timed


def bench_trust_aggregate():
    key = jax.random.PRNGKey(0)
    for C, N in [(16, 1 << 20), (64, 1 << 20)]:
        x = jax.random.normal(key, (C, N), jnp.float32)
        w = jax.nn.softmax(jax.random.normal(key, (C,)))
        us_ref, _ = timed(jax.jit(ref.trust_aggregate_ref), x, w)
        print(f"kernels,trust_aggregate_ref_C{C}_us,{us_ref:.1f}")
        # analytic TPU traffic: kernel = C*N*4 + N*4 bytes single pass
        bytes_kernel = (C + 1) * N * 4
        print(f"kernels,trust_aggregate_traffic_GB_C{C},{bytes_kernel/1e9:.3f}")


def bench_trust_aggregate_vs_jnp(out_json: str = "BENCH_trust_aggregate.json"):
    """Pallas (interpret on CPU) vs jnp oracle at simulator-realistic shapes:
    C = cluster sizes seen by the device-scale engine, N up to 10M params.
    The biggest input is ~1.07 GB (C=256, N=1M); the interpret path takes
    minutes at the largest shapes (it is a correctness oracle, not a speed
    path), so this bench is meant for explicit runs, not the smoke script."""
    shapes = [(8, 1 << 17), (8, 1 << 20), (8, 10_000_000),
              (64, 1 << 17), (64, 1 << 20),
              (256, 1 << 17), (256, 1 << 20)]
    results = []
    key = jax.random.PRNGKey(0)
    for C, N in shapes:
        x = jax.random.normal(key, (C, N), jnp.float32)
        w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (C,)))
        us_jnp, want = timed(jax.jit(ref.trust_aggregate_ref), x, w)
        us_pl, got = timed(
            lambda a, b: trust_aggregate(a, b, interpret=True), x, w)
        err = float(jnp.max(jnp.abs(got - want)))
        row = {
            "C": C, "N": N,
            "jnp_us": round(us_jnp, 1),
            "pallas_interpret_us": round(us_pl, 1),
            "max_abs_err": err,
            # analytic single-pass HBM traffic on the TPU target
            "tpu_traffic_GB": round((C + 1) * N * 4 / 1e9, 4),
            "tpu_us_at_800GBps": round((C + 1) * N * 4 / 800e9 * 1e6, 1),
        }
        results.append(row)
        print(f"kernels,trust_agg_C{C}_N{N},jnp_us={row['jnp_us']},"
              f"pallas_us={row['pallas_interpret_us']},err={err:.2e}")
        del x
    payload = {
        "bench": "trust_aggregate pallas(interpret,CPU) vs jnp oracle",
        "note": ("interpret=True executes the kernel body through the Pallas "
                 "CPU interpreter — a correctness path, not a speed path; "
                 "tpu_us_at_800GBps is the bandwidth-bound roofline for the "
                 "single-pass kernel on a v5e-class part"),
        "timestamp": time.strftime("%Y-%m-%d %H:%M:%S"),
        "device": str(jax.devices()[0]),
        "results": results,
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"kernels,bench_json,{out_json}")


def bench_attention_traffic_model():
    """Flash vs unfused attention HBM bytes at prefill_32k geometry."""
    S, H, d, B = 32768, 16, 256, 2      # per-chip gemma-7b prefill slice
    unfused = (B * H * S * S * 4) * 2 + B * S * H * d * 2 * 3
    flash = B * S * H * d * 2 * 4
    print(f"kernels,attn_unfused_traffic_GB,{unfused/1e9:.1f}")
    print(f"kernels,attn_flash_traffic_GB,{flash/1e9:.1f}")
    print(f"kernels,attn_traffic_reduction_x,{unfused/flash:.0f}")


def main(full: bool = False):
    bench_trust_aggregate()
    bench_attention_traffic_model()
    if full:
        # multi-minute: sweeps the Pallas interpreter up to (8, 10M) and a
        # 1.07 GB (256, 1M) input, writing BENCH_trust_aggregate.json
        bench_trust_aggregate_vs_jnp()


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
