"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall-time and,
more importantly on this CPU container, HBM-traffic *models* for the TPU
target (the numbers the §Perf analysis uses)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref, trust_aggregate
from .common import timed


def bench_trust_aggregate():
    key = jax.random.PRNGKey(0)
    for C, N in [(16, 1 << 20), (64, 1 << 20)]:
        x = jax.random.normal(key, (C, N), jnp.float32)
        w = jax.nn.softmax(jax.random.normal(key, (C,)))
        us_ref, _ = timed(jax.jit(ref.trust_aggregate_ref), x, w)
        print(f"kernels,trust_aggregate_ref_C{C}_us,{us_ref:.1f}")
        # analytic TPU traffic: kernel = C*N*4 + N*4 bytes single pass
        bytes_kernel = (C + 1) * N * 4
        print(f"kernels,trust_aggregate_traffic_GB_C{C},{bytes_kernel/1e9:.3f}")


def bench_attention_traffic_model():
    """Flash vs unfused attention HBM bytes at prefill_32k geometry."""
    S, H, d, B = 32768, 16, 256, 2      # per-chip gemma-7b prefill slice
    unfused = (B * H * S * S * 4) * 2 + B * S * H * d * 2 * 3
    flash = B * S * H * d * 2 * 4
    print(f"kernels,attn_unfused_traffic_GB,{unfused/1e9:.1f}")
    print(f"kernels,attn_flash_traffic_GB,{flash/1e9:.1f}")
    print(f"kernels,attn_traffic_reduction_x,{unfused/flash:.0f}")


def main():
    bench_trust_aggregate()
    bench_attention_traffic_model()


if __name__ == "__main__":
    main()
