"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba-1 architecture.  [arXiv:2410.05355]

d_inner = 2*d_model = 8192, dt_rank = d_model/16 = 256, conv 4.
Natively sub-quadratic: long_500k runs with the O(1) recurrent state.
FL mode A.
"""
import dataclasses

from ..models import ArchConfig
from ..models.config import MAMBA

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    vocab_size=65024,
    d_ff=0,
    block_pattern=(MAMBA,),
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    dt_rank=256,
    tie_embeddings=True,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, dt_rank=8, vocab_size=512)
