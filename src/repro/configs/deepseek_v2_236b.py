"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160 routed top-6 + 2 shared, MLA kv_lora=512.
[arXiv:2405.04434]

MLA: q_lora_rank 1536, kv_lora_rank 512, qk_nope 128 + qk_rope 64,
v_head_dim 128.  Layer 0 is dense (d_ff 12288); layers 1-59 are MoE.
FL mode B (trust_fsdp) — 236B params (DESIGN.md §2).
long_500k skipped (full attention).
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    vocab_size=102400,
    num_heads=128,
    num_kv_heads=128,           # MLA: per-head K/V expanded from the latent
    d_ff=12288,                 # dense layer-0 width
    num_experts=160,
    num_shared_experts=2,
    topk=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mla_absorbed=True,
    activation="silu",
    tie_embeddings=False,
    rope_theta=10000.0,
    fl_mode="trust_fsdp",
    shard_scheme="ep_tp",
    scan_indexed=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=128, num_heads=4, d_ff=256,
    num_experts=4, num_shared_experts=1, topk=2, moe_d_ff=64,
    q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, num_kv_heads=4, vocab_size=512)
