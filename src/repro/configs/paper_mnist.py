"""The paper's own experimental model: 784 -> 200 -> 10 MLP on (synthetic)
MNIST with a 48 x 200 x 10 DQN controlling aggregation frequency (§V).

Not a transformer — used by benchmarks/ and core.mlp; kept in the registry
so `--arch paper-mnist` selects the paper-faithful experiment scale.
"""
from ..core.dqn import DQNConfig
from ..core.async_fl import AsyncFLConfig

CONFIG = AsyncFLConfig(n_devices=16, n_clusters=4)
SMOKE = AsyncFLConfig(n_devices=4, n_clusters=2, sim_seconds=4.0,
                      local_batch=16)
DQN = DQNConfig()
