"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B family card]

Long-context serving (long_500k) uses the sliding-window-4096 variant
(DESIGN.md §4).  FL mode A.
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    vocab_size=152064,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    qkv_bias=True,
    activation="silu",
    tie_embeddings=False,
    rope_theta=1000000.0,
    sliding_variant_window=4096,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512)
