"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000 — GeGLU, head_dim=256.  [arXiv:2403.08295]

Tied embeddings scaled by sqrt(d_model).  long_500k uses the
sliding-window-4096 serving variant.  FL mode A.
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    vocab_size=256000,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    activation="gelu",
    emb_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    sliding_variant_window=4096,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512)
