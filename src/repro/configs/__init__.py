"""Architecture config registry.

``get_config(arch_id)`` returns the full assigned config;
``get_smoke_config(arch_id)`` returns the reduced same-family variant
(<=2 layers-per-pattern-repeat, d_model<=512, <=4 experts) used by the
CPU smoke tests.
"""
from __future__ import annotations

import importlib

from ..models import ArchConfig

ARCH_IDS = [
    "grok_1_314b", "qwen1_5_32b", "chameleon_34b", "falcon_mamba_7b",
    "granite_3_8b", "musicgen_large", "recurrentgemma_2b",
    "deepseek_v2_236b", "gemma_7b", "gemma_2b",
]
# CLI ids use dashes/dots; module names use underscores.
_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({"qwen1.5-32b": "qwen1_5_32b", "grok-1-314b": "grok_1_314b",
                 "paper-mnist": "paper_mnist"})
ARCH_IDS = ARCH_IDS + ["paper_mnist"]


def _module(arch_id: str):
    name = _ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE


def all_arch_ids():
    return [i for i in ARCH_IDS if i != "paper_mnist"]
