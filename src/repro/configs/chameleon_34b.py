"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion VQ image tokens.  [arXiv:2405.09818]

Frontend stub (the one permitted carve-out): Chameleon is *early-fusion* —
images are VQ-VAE token ids inside the same 65536 vocab, so the decoder
consumes plain token ids; the VQ tokenizer itself is stubbed and
``input_specs`` supplies interleaved text+image token ids.
Chameleon uses qk-norm for training stability (paper §2.2) — enabled.
FL mode A.  long_500k skipped (full attention; DESIGN.md §4).
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    arch_type="vlm",
    num_layers=48,
    d_model=8192,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    qk_norm=True,
    activation="silu",
    tie_embeddings=False,
    rope_theta=10000.0,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)
