"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1]

Grok-1 details from the model card: attention-logit tanh softcap 30,
head_dim 128, untied embeddings.  314B total / ~86B active params.
FL mode B (trust_fsdp): a 628 GB bf16 replica cannot fit per-client on a
16-chip TP slice (DESIGN.md §2).
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    vocab_size=131072,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,                 # dense width (unused: all layers MoE)
    num_experts=8,
    topk=2,
    moe_d_ff=32768,
    activation="gelu",
    attn_softcap=30.0,
    tie_embeddings=False,
    rope_theta=10000.0,
    fl_mode="trust_fsdp",
    shard_scheme="fsdp_tp",
    scan_indexed=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, moe_d_ff=256, num_experts=4, topk=2,
    vocab_size=512)
