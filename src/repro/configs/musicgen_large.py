"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284]

Frontend stub (permitted carve-out): the EnCodec neural codec is stubbed —
``input_specs`` supplies K=4 parallel codebook token streams (the delay
pattern's flattened form); the model sums the 4 codebook embeddings and
predicts 4 parallel heads.  MusicGen uses plain MHA (kv=32) and learned
positions; we use RoPE as the substrate's positional scheme (noted
adaptation).  FL mode A.  long_500k skipped (full attention).
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    vocab_size=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    activation="gelu",
    num_codebooks=4,
    tie_embeddings=False,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=256)
