"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=256000 — GeGLU, head_dim=256, MQA.  [arXiv:2403.08295]

long_500k uses the sliding-window-4096 serving variant.  FL mode A.
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    vocab_size=256000,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    activation="gelu",
    emb_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    sliding_variant_window=4096,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, vocab_size=512)
