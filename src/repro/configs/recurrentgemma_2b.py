"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1:2 ratio.  [arXiv:2402.19427]

Griffin block pattern (recurrent, recurrent, local-attention) repeated;
26 = 8 x 3 + 2, the trailing two layers are recurrent (handled as the
unrolled suffix).  Local attention window 2048, head_dim 256, MQA (kv=1).
Natively sub-quadratic -> long_500k runs.  FL mode A.
"""
import dataclasses

from ..models import ArchConfig
from ..models.config import LOCAL, RGLRU

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    vocab_size=256000,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    activation="gelu",
    block_pattern=(RGLRU, RGLRU, LOCAL),
    window=2048,
    lru_width=2560,
    ssm_conv=4,
    emb_scale=True,
    tie_embeddings=True,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=5, d_model=128, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, lru_width=128, window=64, vocab_size=512)
