"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA.  [hf:ibm-granite/granite-3.0-2b-base family]

long_500k uses the sliding-window-4096 serving variant.  FL mode A.
"""
import dataclasses

from ..models import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    arch_type="dense",
    num_layers=40,
    d_model=4096,
    vocab_size=49155,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    activation="silu",
    tie_embeddings=True,
    rope_theta=10000.0,
    sliding_variant_window=4096,
    fl_mode="fedavg_replica",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)
