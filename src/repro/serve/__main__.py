"""Service CLI — the long-running federation front end.

    # start the anomaly-detection service in the background
    PYTHONPATH=src python -m repro.serve start --run-dir /tmp/fl \\
        --scenario autoencoder-anomaly --segment-rounds 25

    PYTHONPATH=src python -m repro.serve status     --run-dir /tmp/fl
    PYTHONPATH=src python -m repro.serve checkpoint --run-dir /tmp/fl
    PYTHONPATH=src python -m repro.serve stop       --run-dir /tmp/fl
    PYTHONPATH=src python -m repro.serve resume     --run-dir /tmp/fl

``start`` resolves a scenario spec, writes it to ``spec.json``, and
(by default) re-execs itself as a detached ``start --foreground`` child —
a spawn, not a fork: forking after jax initializes is unsafe.  The child
owns the pidfile and the segment loop (`service.run_service`); the parent
waits for the pidfile and returns.  ``--foreground`` runs the loop in
this process instead (CI smoke tests, systemd-style supervisors).

``stop`` drops ``control/stop.req`` *and* sends SIGTERM — either alone
suffices; the loop finishes its current segment, writes a final
checkpoint, and exits.  ``resume`` continues a stopped run-dir from its
newest checkpoint, bit-exactly.  ``checkpoint`` on a live service
requests one and waits for it; on a stopped run-dir it prints the newest
checkpoint path (exit 1 if none exists).  ``chaos`` runs the supervised
crash-recovery harness (`chaos.py`).

Waiting commands (``checkpoint --wait`` semantics, ``stop``) poll with
capped exponential backoff instead of a tight fixed sleep, and a timeout
exits with the dedicated code ``EXIT_TIMEOUT`` (3) so supervisors can
tell "still busy" from "failed".  All commands tolerate the stale
pidfile a SIGKILLed daemon leaves behind (`RunDir.running_pid` cleans
it), so a chaos-killed run dir is immediately resumable.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time

from .runner import latest_resumable
from .service import (CKPT_REQ, LOG_FILE, STOP_REQ, RunDir, pid_alive,
                      run_service, service_status)

EXIT_TIMEOUT = 3                        # waited past --timeout; retryable


def _poll(predicate, timeout: float, *, first: float = 0.05,
          cap: float = 1.0):
    """Poll ``predicate`` with capped exponential backoff until it returns
    non-None or ``timeout`` elapses.  Returns the predicate's value, or
    None on timeout.  The backoff keeps short waits snappy (50 ms first
    check) without hammering the filesystem during a long segment."""
    deadline = time.monotonic() + timeout
    delay = first
    while True:
        val = predicate()
        if val is not None:
            return val
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            return None
        time.sleep(min(delay, remaining, cap))
        delay = min(delay * 2.0, cap)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="long-running federation service with checkpointed "
                    "resume")
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p):
        p.add_argument("--run-dir", required=True,
                       help="service instance directory")
        return p

    def loop_flags(p):
        p.add_argument("--segment-rounds", type=int, default=25,
                       help="rounds per scanned segment (checkpoint "
                            "cadence)")
        p.add_argument("--max-segments", type=int, default=None,
                       help="stop after N segments (default: run until "
                            "stopped)")
        p.add_argument("--keep", type=int, default=3,
                       help="checkpoints retained on disk (0 = all)")
        p.add_argument("--foreground", action="store_true",
                       help="run the loop in this process instead of "
                            "daemonizing")
        return p

    p = loop_flags(common(sub.add_parser(
        "start", help="start a fresh service instance")))
    p.add_argument("--scenario", default="autoencoder-anomaly",
                   help="scenario preset for the spec (ignored when the "
                        "run dir already has spec.json)")
    p.add_argument("--seed", type=int, default=None)
    p.add_argument("--spec-file", default=None,
                   help="JSON spec file instead of --scenario")

    loop_flags(common(sub.add_parser(
        "resume", help="continue a stopped run from its newest "
                       "checkpoint")))

    p = common(sub.add_parser("status", help="print service status JSON"))
    p.add_argument("--tail", type=int, default=5,
                   help="trace records to include")
    p.add_argument("--watch", action="store_true",
                   help="render a refreshing terminal dashboard instead "
                        "of JSON")
    p.add_argument("--interval", type=float, default=2.0,
                   help="dashboard refresh period in seconds")
    p.add_argument("--once", action="store_true",
                   help="with --watch: render a single frame and exit "
                        "(CI / piping)")

    common(sub.add_parser(
        "metrics", help="dump the run dir's last metrics snapshot in "
                        "Prometheus text-exposition format"))

    p = common(sub.add_parser(
        "checkpoint", help="request/locate a checkpoint"))
    p.add_argument("--timeout", type=float, default=300.0,
                   help="seconds to wait for a live service to finish "
                        "its segment")

    p = common(sub.add_parser("stop", help="stop a running service"))
    p.add_argument("--timeout", type=float, default=300.0,
                   help="seconds to wait for the final segment + "
                        "checkpoint")

    p = sub.add_parser(
        "pool", help="multi-tenant supervisor: one process drives a "
                     "population of federations into per-member run dirs")
    pool_sub = p.add_subparsers(dest="pool_cmd", required=True)
    p = loop_flags(common(pool_sub.add_parser(
        "start", help="start a fresh pool instance")))
    p.add_argument("--scenario", default="autoencoder-anomaly",
                   help="base-spec scenario preset (ignored when the run "
                        "dir already has pool.json)")
    p.add_argument("--seed", type=int, default=None,
                   help="base seed (member seeds derive via fold_in)")
    p.add_argument("--replicates", type=int, default=4,
                   help="seed replicates of the base spec (population "
                        "size when no --spec-file grid)")
    p.add_argument("--spec-file", default=None,
                   help="PopulationSpec JSON file instead of --scenario")
    loop_flags(common(pool_sub.add_parser(
        "resume", help="continue a stopped pool from the newest common "
                       "verified checkpoint")))
    p = common(pool_sub.add_parser(
        "status", help="print pool status JSON (per-member summary)"))
    p.add_argument("--tail", type=int, default=1,
                   help="trace records per member to include")
    p = common(pool_sub.add_parser("stop", help="stop a running pool"))
    p.add_argument("--timeout", type=float, default=300.0,
                   help="seconds to wait for the final segment + "
                        "checkpoint sweep")

    p = common(sub.add_parser(
        "chaos", help="supervised crash-recovery harness: run to N "
                      "segments, SIGKILLing the service along the way"))
    p.add_argument("--scenario", default="autoencoder-anomaly")
    p.add_argument("--seed", type=int, default=None)
    p.add_argument("--spec-file", default=None)
    p.add_argument("--segment-rounds", type=int, default=5)
    p.add_argument("--total-segments", type=int, default=4,
                   help="verified segments to reach before exiting")
    p.add_argument("--kills", type=int, default=2,
                   help="SIGKILL injections before letting it finish")
    p.add_argument("--keep", type=int, default=0,
                   help="checkpoints retained (0 = all)")
    p.add_argument("--max-restarts", type=int, default=8,
                   help="consecutive no-progress restarts tolerated")
    return ap


# --------------------------------------------------------------------- #
def _resolve_spec(args):
    from repro.api import scenarios  # noqa: F401  (populates SCENARIOS)
    from repro.api.registry import SCENARIOS
    from repro.api.spec import FederationSpec
    if args.spec_file:
        with open(args.spec_file) as f:
            spec = FederationSpec.from_dict(json.load(f))
    else:
        spec = SCENARIOS.get(args.scenario)()
    if args.seed is not None:
        spec = spec.replace(seed=args.seed)
    return spec.validate()


def _loop_argv(args) -> list:
    argv = ["--run-dir", args.run_dir, "--foreground",
            "--segment-rounds", str(args.segment_rounds),
            "--keep", str(args.keep)]
    if args.max_segments is not None:
        argv += ["--max-segments", str(args.max_segments)]
    return argv


def _spawn(rd: RunDir, child_argv: list) -> int:
    """Detach a ``--foreground`` child (spawn, not fork — jax threads)."""
    with open(rd.path(LOG_FILE), "a") as log:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.serve"] + child_argv,
            stdout=log, stderr=subprocess.STDOUT,
            stdin=subprocess.DEVNULL, start_new_session=True)
    deadline = time.monotonic() + 120.0
    while time.monotonic() < deadline:
        if rd.running_pid() == proc.pid:
            print(f"started pid {proc.pid} run-dir {rd.root}")
            return 0
        if proc.poll() is not None:
            print(f"error: service exited with code {proc.returncode}; "
                  f"see {rd.path(LOG_FILE)}", file=sys.stderr)
            return 1
        time.sleep(0.05)
    print(f"error: service pid {proc.pid} did not report ready; see "
          f"{rd.path(LOG_FILE)}", file=sys.stderr)
    return 1


def _refuse_if_running(rd: RunDir) -> bool:
    pid = rd.running_pid()
    if pid is not None:
        print(f"error: service already running (pid {pid}) in {rd.root}",
              file=sys.stderr)
        return True
    return False


# --------------------------------------------------------------------- #
def cmd_start(args) -> int:
    rd = RunDir(args.run_dir).ensure()
    if _refuse_if_running(rd):
        return 1
    keep = args.keep if args.keep > 0 else None
    if os.path.exists(rd.spec_path):
        pass                            # re-exec'd child / explicit reuse
    else:
        if latest_resumable(rd.ckpt_dir) is not None:
            print(f"error: {rd.root} has checkpoints but no spec.json; "
                  "refusing to guess — use a fresh --run-dir",
                  file=sys.stderr)
            return 1
        try:
            rd.write_spec(_resolve_spec(args))
        except (KeyError, ValueError, OSError) as e:
            print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
            return 1
    if latest_resumable(rd.ckpt_dir) is not None:
        print(f"error: {rd.root} already has checkpoints; use "
              "`python -m repro.serve resume` (or a fresh --run-dir)",
              file=sys.stderr)
        return 1
    if not args.foreground:
        return _spawn(rd, ["start"] + _loop_argv(args))
    run_service(rd.root, segment_rounds=args.segment_rounds,
                max_segments=args.max_segments, keep=keep, resume=False)
    return 0


def cmd_resume(args) -> int:
    rd = RunDir(args.run_dir)
    if _refuse_if_running(rd):
        return 1
    if latest_resumable(rd.ckpt_dir) is None:
        print(f"error: no complete checkpoint under {rd.ckpt_dir}",
              file=sys.stderr)
        return 1
    keep = args.keep if args.keep > 0 else None
    if not args.foreground:
        return _spawn(rd, ["resume"] + _loop_argv(args))
    run_service(rd.root, segment_rounds=args.segment_rounds,
                max_segments=args.max_segments, keep=keep, resume=True)
    return 0


def cmd_status(args) -> int:
    if not getattr(args, "watch", False):
        print(json.dumps(service_status(args.run_dir, tail=args.tail),
                         indent=2))
        return 0
    from .dashboard import render
    try:
        while True:
            frame = render(service_status(args.run_dir, tail=args.tail))
            if args.once:
                print(frame)
                return 0
            # repaint in place: clear screen + home, then the frame
            sys.stdout.write("\x1b[2J\x1b[H" + frame + "\n")
            sys.stdout.flush()
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


def cmd_metrics(args) -> int:
    from repro.obs import MetricsRegistry
    from .service import load_run_metrics
    snap = load_run_metrics(args.run_dir)
    if snap is None:
        print(f"error: no metrics snapshots under {args.run_dir} "
              "(has the service completed a segment?)", file=sys.stderr)
        return 1
    sys.stdout.write(MetricsRegistry.from_snapshot(snap).to_prometheus())
    return 0


def cmd_checkpoint(args) -> int:
    rd = RunDir(args.run_dir)
    pid = rd.running_pid()
    before = latest_resumable(rd.ckpt_dir)
    if pid is None:                     # stopped: just locate the newest
        if before is None:
            print(f"error: no complete checkpoint under {rd.ckpt_dir}",
                  file=sys.stderr)
            return 1
        print(before[0])
        return 0
    rd.ensure().request(CKPT_REQ)
    before_step = before[1]["step"] if before else -1

    def fresh_ckpt():
        now = latest_resumable(rd.ckpt_dir)
        if now is not None and now[1]["step"] > before_step:
            return now
        if not pid_alive(pid):          # service exited meanwhile: its
            now = latest_resumable(rd.ckpt_dir)   # farewell ckpt counts
            return now if now is not None else ("dead",)
        return None

    got = _poll(fresh_ckpt, args.timeout)
    if got is None:
        print(f"error: no checkpoint within {args.timeout:.0f}s (segment "
              "in flight?) — retry with a larger --timeout",
              file=sys.stderr)
        return EXIT_TIMEOUT
    if got == ("dead",):
        print("error: service died without leaving a checkpoint",
              file=sys.stderr)
        return 1
    print(got[0])
    return 0


def cmd_stop(args) -> int:
    rd = RunDir(args.run_dir)
    pid = rd.running_pid()
    if pid is None:
        print("service not running")
        return 0
    rd.ensure().request(STOP_REQ)
    try:
        os.kill(pid, signal.SIGTERM)
    except OSError:
        pass
    gone = _poll(lambda: (True if not pid_alive(pid) else None),
                 args.timeout)
    if gone:
        state = rd.read_state() or {}
        print(f"stopped pid {pid} at round {state.get('rounds')}")
        return 0
    print(f"error: pid {pid} still alive after {args.timeout:.0f}s "
          "(segment in flight?) — retry or kill -9", file=sys.stderr)
    return EXIT_TIMEOUT


def cmd_chaos(args) -> int:
    from .chaos import run_supervised
    rd = RunDir(args.run_dir)
    if _refuse_if_running(rd):
        return 1
    try:
        summary = run_supervised(
            args.run_dir, total_segments=args.total_segments,
            segment_rounds=args.segment_rounds, kills=args.kills,
            keep=args.keep, scenario=args.scenario,
            spec_file=args.spec_file, seed=args.seed,
            max_restarts=args.max_restarts,
            log=lambda m: print(m, file=sys.stderr))  # stdout: JSON only
    except RuntimeError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    print(json.dumps(summary, indent=2))
    return 0


# --------------------------------------------------------------------- #
# pool (multi-tenant) commands
# --------------------------------------------------------------------- #
def _resolve_pool_spec(args):
    from repro.api import scenarios  # noqa: F401  (populates SCENARIOS)
    from repro.api.registry import SCENARIOS
    from repro.pop import PopulationSpec
    if args.spec_file:
        with open(args.spec_file) as f:
            pspec = PopulationSpec.from_dict(json.load(f))
    else:
        base = SCENARIOS.get(args.scenario)()
        pspec = PopulationSpec(base=base, replicates=args.replicates)
    if args.seed is not None:
        pspec = pspec.replace(base=pspec.base.replace(seed=args.seed))
    return pspec.validate()


def cmd_pool_start(args) -> int:
    from .pool import (POOL_SPEC_FILE, common_checkpoint_step,
                       ensure_pool_dir, load_pool_spec, run_pool,
                       write_pool_spec)
    rd = ensure_pool_dir(args.run_dir)
    if _refuse_if_running(rd):
        return 1
    keep = args.keep if args.keep > 0 else None
    if not os.path.exists(rd.path(POOL_SPEC_FILE)):
        try:
            write_pool_spec(rd.root, _resolve_pool_spec(args))
        except (KeyError, ValueError, OSError) as e:
            print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
            return 1
    pspec = load_pool_spec(rd.root)
    dirs = [os.path.join(rd.root, "members", f"{b:03d}")
            for b in range(pspec.size)]
    if common_checkpoint_step(dirs) is not None:
        print(f"error: {rd.root} already has member checkpoints; use "
              "`python -m repro.serve pool resume` (or a fresh "
              "--run-dir)", file=sys.stderr)
        return 1
    if not args.foreground:
        return _spawn(rd, ["pool", "start"] + _loop_argv(args))
    run_pool(rd.root, segment_rounds=args.segment_rounds,
             max_segments=args.max_segments, keep=keep, resume=False)
    return 0


def cmd_pool_resume(args) -> int:
    from .pool import (common_checkpoint_step, load_pool_spec, run_pool)
    rd = RunDir(args.run_dir)
    if _refuse_if_running(rd):
        return 1
    try:
        pspec = load_pool_spec(rd.root)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    dirs = [os.path.join(rd.root, "members", f"{b:03d}")
            for b in range(pspec.size)]
    if common_checkpoint_step(dirs) is None:
        print(f"error: no common verified checkpoint across the "
              f"{pspec.size} member dirs under {rd.root}",
              file=sys.stderr)
        return 1
    keep = args.keep if args.keep > 0 else None
    if not args.foreground:
        return _spawn(rd, ["pool", "resume"] + _loop_argv(args))
    run_pool(rd.root, segment_rounds=args.segment_rounds,
             max_segments=args.max_segments, keep=keep, resume=True)
    return 0


def cmd_pool_status(args) -> int:
    from .pool import pool_status
    print(json.dumps(pool_status(args.run_dir, tail=args.tail), indent=2))
    return 0


def cmd_pool(args) -> int:
    return {"start": cmd_pool_start, "resume": cmd_pool_resume,
            "status": cmd_pool_status,
            "stop": cmd_stop}[args.pool_cmd](args)


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return {"start": cmd_start, "resume": cmd_resume,
            "status": cmd_status, "metrics": cmd_metrics,
            "checkpoint": cmd_checkpoint, "pool": cmd_pool,
            "stop": cmd_stop, "chaos": cmd_chaos}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
