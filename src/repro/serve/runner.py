"""Segmented execution with checkpointed resume (the service's core loop).

`SegmentRunner` drives `DeviceScaleEngine.run_scanned(K)` in repeated
K-round segments and checkpoints the **full resumable state** after each:

* the `FleetState` pytree — twins, reputations, channel, cluster/global
  params, the Eqn-12 Lyapunov backlog, the round counter, and the typed
  JAX PRNG-key leaf (round-tripped through `repro.checkpoint`'s
  ``__key__:`` marker so the restored key continues the exact stream);
* the per-cluster event-time vector `run_scanned` carries across calls;
* the controller's scan-policy carry (the deployed DQN net; fixed and
  Lyapunov carries are empty — the backlog lives in `FleetState.queue`);
* a JSON manifest sidecar with the round counter and the float64 energy
  tally.  The tally cannot ride in the npz — with x64 disabled a
  ``jnp.asarray`` round-trip would truncate it to f32 — but Python's JSON
  repr round-trips doubles exactly, so the manifest is the bit-exact home.

Both files land atomically (``.tmp`` + ``os.replace``); a checkpoint is
*complete* only when its manifest exists, so `latest_resumable` skips an
npz whose manifest write was lost to a crash.  The manifest additionally
records the npz's byte size and CRC32 content digest; `latest_resumable`
walks newest-first and returns the first checkpoint whose bytes still
match (``verify_checkpoint``), so a truncated or bit-rotted npz degrades
to the previous good checkpoint instead of a crash-loop on restore.
Restore builds a **fresh**
federation from the same spec (device data, cluster assignments, and the
malicious mask all derive deterministically from ``spec.seed``), then
overwrites the resumable leaves — after which continuing produces the
exact trace an uninterrupted segmented run would (`tests/test_serve.py`
asserts equality down to the f64 energy column).
"""
from __future__ import annotations

import json
import os
import re
import zlib
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint import load_checkpoint, save_checkpoint

_MANIFEST_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _manifest_path(npz_path: str) -> str:
    return npz_path[: -len(".npz")] + ".json"


def _atomic_write_json(path: str, obj: Dict[str, Any]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _file_digest(path: str) -> Tuple[int, int]:
    """(byte size, CRC32) of a file, streamed in 1 MiB chunks."""
    size, crc = 0, 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            size += len(chunk)
            crc = zlib.crc32(chunk, crc)
    return size, crc & 0xFFFFFFFF


def verify_checkpoint(npz_path: str) -> bool:
    """True when the npz's bytes still match its manifest digest.

    Legacy manifests (pre-digest) verify by existence alone — they were
    written before the integrity field, and rejecting them would strand
    old runs.  A missing npz or manifest is corrupt, not legacy.
    """
    mpath = _manifest_path(npz_path)
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError):
        return False
    if "crc32" not in manifest:
        return os.path.exists(npz_path)
    try:
        size, crc = _file_digest(npz_path)
    except OSError:
        return False
    return (size == manifest.get("bytes") and crc == manifest["crc32"])


def _resumable_tree(federation) -> Dict[str, Any]:
    engine = federation.engine
    tree = dict(engine.resumable_state())          # fleet + event times
    tree["policy"] = federation.controller.scan_policy().state
    return tree


def save_resumable(federation, ckpt_dir: str, *, segment: int,
                   keep: Optional[int] = 3) -> str:
    """Checkpoint a federation's full resumable state; returns the npz path.

    ``keep`` bounds disk use for unbounded runs: after a successful write,
    all but the newest ``keep`` complete checkpoints are deleted (None
    keeps everything).
    """
    engine = federation.engine
    step = int(engine.round)
    fname = save_checkpoint(ckpt_dir, step, _resumable_tree(federation))
    # manifest second: its presence marks the checkpoint complete, the
    # exact-f64 energy tally lives here (npz would truncate it to f32),
    # and the digest is what restore verifies the npz bytes against
    size, crc = _file_digest(fname)
    _atomic_write_json(_manifest_path(fname), {
        "step": step,
        "rounds": step,
        "energy": float(engine.energy_used),
        "segment": int(segment),
        "bytes": size,
        "crc32": crc,
    })
    if keep is not None:
        prune_checkpoints(ckpt_dir, keep=keep)
    return fname


def list_resumable(ckpt_dir: str):
    """Complete checkpoints (npz + manifest) in the directory, oldest
    first, as ``(step, npz_path)`` pairs."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        m = _MANIFEST_RE.match(f)
        if not m:
            continue
        path = os.path.join(ckpt_dir, f)
        if os.path.exists(_manifest_path(path)):
            out.append((int(m.group(1)), path))
    return sorted(out)


def latest_resumable(ckpt_dir: str
                     ) -> Optional[Tuple[str, Dict[str, Any]]]:
    """Newest *verified* checkpoint as ``(npz_path, manifest)``, or None.

    Walks newest-first, skipping any checkpoint whose npz bytes no longer
    match the manifest digest — the automatic fallback that lets a service
    resume from the last good state after a torn or corrupted write."""
    for _, path in reversed(list_resumable(ckpt_dir)):
        if verify_checkpoint(path):
            with open(_manifest_path(path)) as f:
                return path, json.load(f)
    return None


def prune_checkpoints(ckpt_dir: str, keep: int) -> None:
    """Delete all but the newest ``keep`` *verified* checkpoints.

    Corrupt checkpoints are deleted outright (they can never be restored),
    so after pruning the ``keep`` newest survivors are all restorable."""
    verified = [p for _, p in list_resumable(ckpt_dir)
                if verify_checkpoint(p)]
    doomed = verified[:-keep or None]
    doomed += [p for _, p in list_resumable(ckpt_dir) if p not in verified]
    for path in doomed:
        for victim in (path, _manifest_path(path)):
            try:
                os.remove(victim)
            except OSError:
                pass


def restore_resumable(federation, ckpt_dir: str) -> Dict[str, Any]:
    """Restore a federation to the newest checkpoint; returns its manifest.

    The federation must have been built from the *same spec* (same seed:
    data, assignments, and masks regenerate deterministically) — only the
    resumable leaves are overwritten.
    """
    found = latest_resumable(ckpt_dir)
    if found is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    path, manifest = found
    tree = load_checkpoint(path, like=_resumable_tree(federation))
    federation.engine.restore_resumable(
        {"fleet": tree["fleet"], "times": tree["times"]},
        rounds=manifest["rounds"], energy=manifest["energy"])
    restore_policy = getattr(federation.controller,
                             "restore_policy_state", None)
    if restore_policy is not None:      # DQN: adopt the deployed net
        restore_policy(tree["policy"])
    return manifest


def truncate_jsonl_trace(path: str, max_round: int) -> int:
    """Drop trace records newer than the checkpoint being resumed from.

    A crash can land between trace appends and the segment checkpoint;
    on resume the re-run segment would then duplicate those rounds.  The
    file is rewritten through a temp + ``os.replace`` keeping records with
    ``round <= max_round`` (streaming, so multi-GB traces stay cheap).
    Returns the number of dropped records; a missing file is a no-op.
    """
    if not os.path.exists(path):
        return 0
    tmp = path + ".tmp"
    dropped = 0
    with open(path) as src, open(tmp, "w") as dst:
        for line in src:
            stripped = line.strip()
            if not stripped:
                continue
            try:
                rec = json.loads(stripped)
            except json.JSONDecodeError:
                dropped += 1            # torn final line from a crash
                continue
            if rec.get("round", 0) > max_round:
                dropped += 1
                continue
            dst.write(stripped + "\n")
        dst.flush()
        os.fsync(dst.fileno())
    os.replace(tmp, path)
    return dropped


class SegmentRunner:
    """Run a federation in checkpointed K-round segments.

    Thin and synchronous — the service layer owns signals, pidfiles, and
    status; tests drive this class directly for the bit-parity guarantees.
    """

    def __init__(self, federation, ckpt_dir: str, *,
                 segment_rounds: int = 25, keep: Optional[int] = 3,
                 eval_final: bool = True, obs=None):
        self.federation = federation
        self.ckpt_dir = str(ckpt_dir)
        self.segment_rounds = int(segment_rounds)
        self.keep = keep
        self.eval_final = eval_final
        self.segment = 0
        # optional `repro.obs.EngineObs`: wraps the segment/checkpoint in
        # timing spans and feeds the checkpoint-latency metrics; the
        # engine-side hooks attach separately via `engine.set_obs`
        self.obs = obs

    # ------------------------------------------------------------------ #
    def maybe_resume(self) -> Optional[Dict[str, Any]]:
        """Adopt the newest checkpoint if one exists; returns its manifest
        (None for a fresh start)."""
        if latest_resumable(self.ckpt_dir) is None:
            return None
        manifest = restore_resumable(self.federation, self.ckpt_dir)
        self.segment = int(manifest.get("segment", 0))
        return manifest

    def run_segment(self):
        """One K-round scanned segment followed by a checkpoint.

        Under telemetry the whole thing nests in a ``span("segment")``
        whose children are the engine's fenced round/host_sync/eval spans
        and the ``span("checkpoint")`` below — one emitted timing tree
        per segment in ``metrics.jsonl``."""
        if self.obs is None:
            trace = self.federation.engine.run_scanned(
                self.segment_rounds, eval_final=self.eval_final)
            self.segment += 1
            self.checkpoint()
            return trace
        with self.obs.span("segment", segment=self.segment + 1,
                           rounds=self.segment_rounds):
            trace = self.federation.engine.run_scanned(
                self.segment_rounds, eval_final=self.eval_final)
            self.segment += 1
            self.checkpoint()
        self.obs.registry.counter(
            "service_segments_total", "segments completed").inc(1)
        return trace

    def checkpoint(self) -> str:
        if self.obs is None:
            return save_resumable(self.federation, self.ckpt_dir,
                                  segment=self.segment, keep=self.keep)
        with self.obs.span("checkpoint", segment=self.segment) as sp:
            path = save_resumable(self.federation, self.ckpt_dir,
                                  segment=self.segment, keep=self.keep)
            try:
                sp.attrs["bytes"] = os.path.getsize(path)
            except OSError:
                pass
        self.obs.on_checkpoint(sp.dur_s, sp.attrs.get("bytes", 0))
        return path

    # ------------------------------------------------------------------ #
    @property
    def rounds(self) -> int:
        return int(self.federation.engine.round)

    @property
    def energy(self) -> float:
        return float(self.federation.engine.energy_used)
