"""Chaos harness: SIGKILL the service mid-run, supervise the recovery.

`run_supervised` drives a run dir to ``total_segments`` checkpointed
segments through repeated child processes, injecting ``kills`` SIGKILLs
along the way — each lands right after a fresh checkpoint, i.e. while the
next segment (and possibly a checkpoint write) is in flight, the worst
spot short of corrupting the npz on purpose.  SIGKILL skips every
``finally`` in the service: no farewell state write, no pidfile cleanup,
possibly a torn ``.tmp`` or half-written npz.  Recovery leans on exactly
the guarantees the serve layer advertises:

* `RunDir.running_pid` clears the stale pidfile, so ``resume`` is not
  refused;
* `latest_resumable` returns the newest checkpoint whose CRC32 digest
  still matches, silently stepping over torn writes;
* ``resume`` truncates ``trace.jsonl`` back to the checkpointed round, so
  the reconstructed trace is record-identical to an uninterrupted run
  (``tests/test_serve.py`` byte-compares the two).

Restarts use capped exponential backoff; a child that dies repeatedly
without advancing the checkpoint frontier exhausts ``max_restarts`` and
raises — a crash-*loop* is a bug, a crash is routine.

CLI: ``python -m repro.serve chaos --run-dir ... --total-segments 4
--kills 2`` (see `__main__.py`); `benchmarks/smoke.sh` runs this in CI.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional

from repro.api.records import JsonlSink
from repro.obs import MetricsRegistry, snapshot_record

from .runner import latest_resumable
from .service import LOG_FILE, METRICS_FILE, RunDir


def segments_done(ckpt_dir: str) -> int:
    """Segment counter of the newest *verified* checkpoint (0 if none)."""
    found = latest_resumable(ckpt_dir)
    return int(found[1].get("segment", 0)) if found else 0


def spawn_service(run_dir: str, *, segment_rounds: int, max_segments: int,
                  keep: int = 0, scenario: Optional[str] = None,
                  spec_file: Optional[str] = None,
                  seed: Optional[int] = None) -> subprocess.Popen:
    """Spawn one ``--foreground`` service child for the run dir.

    Picks ``resume`` when a verified checkpoint exists, else ``start``
    (with the scenario/spec flags).  ``start_new_session`` isolates the
    child so the harness's SIGKILL never leaks to the supervisor."""
    rd = RunDir(run_dir).ensure()
    if latest_resumable(rd.ckpt_dir) is not None:
        argv = ["resume"]
    else:
        argv = ["start"]
        if spec_file:
            argv += ["--spec-file", spec_file]
        elif scenario:
            argv += ["--scenario", scenario]
        if seed is not None:
            argv += ["--seed", str(seed)]
    argv += ["--run-dir", run_dir, "--foreground",
             "--segment-rounds", str(segment_rounds),
             "--max-segments", str(max_segments), "--keep", str(keep)]
    log = open(rd.path(LOG_FILE), "a")
    try:
        return subprocess.Popen(
            [sys.executable, "-m", "repro.serve"] + argv,
            stdout=log, stderr=subprocess.STDOUT,
            stdin=subprocess.DEVNULL, start_new_session=True)
    finally:
        log.close()


def run_supervised(run_dir: str, *, total_segments: int,
                   segment_rounds: int = 5, kills: int = 0,
                   keep: int = 0, scenario: Optional[str] = None,
                   spec_file: Optional[str] = None,
                   seed: Optional[int] = None, max_restarts: int = 8,
                   backoff0: float = 0.1, backoff_cap: float = 5.0,
                   poll: float = 0.05, kill_timeout: float = 600.0,
                   log=print) -> Dict[str, Any]:
    """Supervise the run dir to ``total_segments`` verified segments.

    While ``kills`` remain, each child is SIGKILLed as soon as it lands a
    checkpoint beyond the frontier; afterwards children run to completion.
    Any abnormal child exit (killed or crashed) triggers a restart after
    capped exponential backoff — but only ``max_restarts`` times without
    forward progress, so a deterministic crash surfaces instead of
    looping.  Returns a summary dict (segments/rounds/kills/restarts).
    """
    rd = RunDir(run_dir)
    kills_left = int(kills)
    restarts = 0
    stalls = 0                          # consecutive restarts w/o progress
    backoff = backoff0
    events: List[Dict[str, Any]] = []
    # supervisor-side telemetry: restart/kill counters snapshot into the
    # run dir's metrics.jsonl under source="chaos" — the child's
    # source="service" snapshots merge with these at read time
    # (`load_run_metrics`), so one file tells the whole recovery story
    reg = MetricsRegistry()
    m_kills = reg.counter("chaos_sigkills_total",
                          "SIGKILLs injected by the chaos harness")
    m_restarts = reg.counter("chaos_restarts_total",
                             "service children restarted")
    m_segments = reg.gauge("chaos_segments",
                           "verified checkpoint frontier")
    msink = JsonlSink(rd.path(METRICS_FILE))

    def snap() -> None:
        m_segments.set(segments_done(rd.ckpt_dir))
        msink.append(snapshot_record(reg, source="chaos", ts=time.time()))
    while segments_done(rd.ckpt_dir) < total_segments:
        done = segments_done(rd.ckpt_dir)
        proc = spawn_service(
            run_dir, segment_rounds=segment_rounds,
            max_segments=total_segments - done, keep=keep,
            scenario=scenario, spec_file=spec_file, seed=seed)
        if kills_left > 0:
            deadline = time.monotonic() + kill_timeout
            while (proc.poll() is None
                   and segments_done(rd.ckpt_dir) <= done
                   and time.monotonic() < deadline):
                time.sleep(poll)
            if proc.poll() is None:
                os.kill(proc.pid, signal.SIGKILL)
                proc.wait()
                kills_left -= 1
                m_kills.inc(1)
                snap()
                events.append({"event": "sigkill", "pid": proc.pid,
                               "after_segment":
                                   segments_done(rd.ckpt_dir)})
                log(f"chaos: SIGKILLed pid {proc.pid} after segment "
                    f"{segments_done(rd.ckpt_dir)}")
        else:
            proc.wait()
        if segments_done(rd.ckpt_dir) >= total_segments:
            break
        if segments_done(rd.ckpt_dir) > done:
            stalls = 0                  # forward progress resets the cap
            backoff = backoff0
        else:
            stalls += 1
            if stalls > max_restarts:
                raise RuntimeError(
                    f"chaos: {max_restarts} restarts without progress in "
                    f"{run_dir} (exit {proc.returncode}); see "
                    f"{rd.path(LOG_FILE)}")
        # restart whatever the exit code: a clean exit with segments still
        # owed (stop request raced the count) resumes just like a crash
        restarts += 1
        m_restarts.inc(1)
        snap()
        events.append({"event": "restart", "backoff": backoff,
                       "exit": proc.returncode})
        time.sleep(backoff)
        backoff = min(backoff * 2.0, backoff_cap)
    snap()                              # final frontier + counter state
    found = latest_resumable(rd.ckpt_dir)
    return {
        "run_dir": run_dir,
        "segments": segments_done(rd.ckpt_dir),
        "rounds": int(found[1]["rounds"]) if found else 0,
        "kills": int(kills) - kills_left,
        "restarts": restarts,
        "events": events,
    }
