"""repro.serve — long-running federation service with checkpointed resume.

The batch API (`repro.api.Federation.run`) answers "run this experiment";
this package answers "keep this federation running": segments of
`run_scanned(K)` rounds, a full resumable checkpoint after each, a
streamed JSONL trace, and a file-protocol CLI (``python -m repro.serve``)
with start / status / metrics / checkpoint / resume / stop / chaos.
Resume is bit-exact — a stopped-and-resumed run continues the precise
trace an uninterrupted run would have produced, even across a SIGKILL:
manifests carry a CRC32 content digest, restore falls back to the newest
*verified* checkpoint, and the chaos harness (`chaos.run_supervised`)
exercises the whole kill → verify → resume path under supervision
(API.md "Service mode" / "Fault injection & recovery").  Telemetry
(`repro.obs`) streams into ``metrics.jsonl`` beside the trace:
``status --watch`` renders the live dashboard and ``metrics`` dumps the
Prometheus snapshot (API.md "Observability").
"""
from .chaos import run_supervised, spawn_service
from .pool import (common_checkpoint_step, load_pool_spec, member_dir,
                   pool_status, run_pool, write_pool_spec)
from .runner import (SegmentRunner, latest_resumable, list_resumable,
                     prune_checkpoints, restore_resumable, save_resumable,
                     truncate_jsonl_trace, verify_checkpoint)
from .service import (RunDir, last_spans, load_run_metrics, run_service,
                      service_status)

__all__ = ["SegmentRunner", "latest_resumable", "list_resumable",
           "prune_checkpoints", "restore_resumable", "save_resumable",
           "truncate_jsonl_trace", "verify_checkpoint", "RunDir",
           "run_service", "service_status", "run_supervised",
           "spawn_service", "load_run_metrics", "last_spans",
           "run_pool", "pool_status", "member_dir", "load_pool_spec",
           "write_pool_spec", "common_checkpoint_step"]
