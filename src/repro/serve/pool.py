"""Multi-tenant sweep serving: one process, B checkpointed federations.

A pool instance is a directory (``--run-dir``):

    pool_dir/
      pool.json        the resolved PopulationSpec (config round-trip form)
      serve.json       live pool state (status/pid/segment/rounds)
      serve.pid        pid of the running supervisor process
      serve.log        stdout+stderr of a daemonized supervisor
      metrics.jsonl    pool telemetry (``pop``-labeled series + span trees)
      control/         drop-box: ``stop.req`` (polled between segments)
      members/
        000/           a full single-tenant run dir per member:
          spec.json      the member's expanded FederationSpec
          trace.jsonl    the member's streamed RoundRecords
          checkpoints/   ckpt_XXXXXXXX.npz + manifests (runner.py format)
        001/ ...

Every member directory speaks the *existing* single-tenant file protocol
— ``python -m repro.serve status --run-dir pool_dir/members/000`` works,
and a member's checkpoints are byte-compatible with a standalone service
run of the same expanded spec.  What the pool adds is the shared cadence:
one `PopulationEngine.run_scanned` call advances all B tenants together
(a single vmapped device program), then each member checkpoints into its
own dir.

Resume picks the **maximum step every member has a verified checkpoint
for** — a crash mid-checkpoint-sweep leaves a ragged frontier (members
written before the crash are one segment ahead), and restoring the ragged
maxima would tear the shared cadence.  Each member restores from that
common step and its trace is truncated back to it, so the continued
per-member streams are bit-identical to an uninterrupted run's
(`tests/test_pop.py` pins this against a single-tenant service run).

Telemetry publishes through `repro.obs` with the member index as a
``pop`` label; the registry's cardinality guard collapses huge
populations into the overflow series instead of unbounded growth.
"""
from __future__ import annotations

import json
import os
import signal
import time
from typing import Any, Dict, List, Optional

from repro.api.records import JsonlSink, tail_jsonl
from repro.checkpoint import load_checkpoint
from repro.obs import EngineObs
from repro.pop import PopulationEngine, PopulationSpec

from .runner import (_resumable_tree, list_resumable, save_resumable,
                     truncate_jsonl_trace, verify_checkpoint)
from .service import (CKPT_REQ, CONTROL_DIR, STOP_REQ, RunDir,
                      atomic_write_json, read_json)

POOL_SPEC_FILE = "pool.json"
MEMBERS_DIR = "members"


# --------------------------------------------------------------------- #
# pool run-dir primitives
# --------------------------------------------------------------------- #
def member_dir(pool_dir: str, b: int) -> str:
    return os.path.join(str(pool_dir), MEMBERS_DIR, f"{b:03d}")


def write_pool_spec(pool_dir: str, pspec: PopulationSpec) -> None:
    atomic_write_json(os.path.join(str(pool_dir), POOL_SPEC_FILE),
                      pspec.to_dict())


def load_pool_spec(pool_dir: str) -> PopulationSpec:
    path = os.path.join(str(pool_dir), POOL_SPEC_FILE)
    d = read_json(path)
    if d is None:
        raise FileNotFoundError(
            f"{path} missing or unreadable — is {pool_dir!r} a pool run "
            "dir?")
    return PopulationSpec.from_dict(d)


def ensure_pool_dir(pool_dir: str) -> RunDir:
    """Pool-root layout: control drop-box + members/, but no root-level
    checkpoints dir — checkpoints live per tenant."""
    rd = RunDir(pool_dir)
    os.makedirs(rd.path(CONTROL_DIR), exist_ok=True)
    os.makedirs(rd.path(MEMBERS_DIR), exist_ok=True)
    return rd


def common_checkpoint_step(member_dirs: List[str]) -> Optional[int]:
    """The newest step for which *every* member has a verified checkpoint
    (None when no step is shared).  The pool checkpoints members
    sequentially after each segment, so a crash leaves a ragged frontier;
    the common step is the last cadence point the whole population
    reached."""
    common: Optional[set] = None
    for d in member_dirs:
        ckpt_dir = os.path.join(d, "checkpoints")
        steps = {s for s, p in list_resumable(ckpt_dir)
                 if verify_checkpoint(p)}
        common = steps if common is None else (common & steps)
        if not common:
            return None
    return max(common) if common else None


def restore_member_at(pop: PopulationEngine, b: int, ckpt_dir: str,
                      step: int) -> Dict[str, Any]:
    """Restore population member ``b`` from its checkpoint at ``step``
    (not necessarily the newest — resume targets the common step);
    returns the manifest."""
    path = next((p for s, p in list_resumable(ckpt_dir) if s == step),
                None)
    if path is None:
        raise FileNotFoundError(
            f"member {b}: no checkpoint at step {step} under {ckpt_dir}")
    member = pop.member(b)
    tree = load_checkpoint(path, like=_resumable_tree(member))
    with open(path[: -len(".npz")] + ".json") as f:
        manifest = json.load(f)
    member.engine.restore_resumable(
        {"fleet": tree["fleet"], "times": tree["times"]},
        rounds=manifest["rounds"], energy=manifest["energy"])
    restore_policy = getattr(member.controller, "restore_policy_state",
                             None)
    if restore_policy is not None:
        restore_policy(tree["policy"])
    return manifest


# --------------------------------------------------------------------- #
# the supervisor loop
# --------------------------------------------------------------------- #
def run_pool(pool_dir: str, *, segment_rounds: int = 25,
             max_segments: Optional[int] = None, keep: Optional[int] = 3,
             resume: bool = False, log=print) -> Dict[str, Any]:
    """Drive a population through checkpointed segments until stopped.

    Mirrors `service.run_service`: signals and ``control/stop.req`` both
    set the same stop flag, every segment ends with a full checkpoint
    sweep, and the final state dict is returned.  ``resume=True``
    restores every member from the maximum common verified step and
    truncates each member's trace back to it.
    """
    rd = ensure_pool_dir(pool_dir)
    pspec = load_pool_spec(pool_dir).validate()
    specs = pspec.expand()
    B = len(specs)

    stopping = {"flag": False}

    def _on_signal(signum, frame):
        stopping["flag"] = True

    prev = {sig: signal.signal(sig, _on_signal)
            for sig in (signal.SIGTERM, signal.SIGINT)}
    rd.write_pid()
    try:
        mrds = []
        for b, spec in enumerate(specs):
            mrd = RunDir(member_dir(pool_dir, b)).ensure()
            if not os.path.exists(mrd.spec_path):
                mrd.write_spec(spec)
            mrds.append(mrd)

        pop = PopulationEngine(specs, sharding=pspec.sharding,
                               pop_axis=pspec.pop_axis())

        obs = EngineObs(sink=JsonlSink(rd.metrics_path), source="pool")
        segment = 0
        if resume:
            step = common_checkpoint_step([m.root for m in mrds])
            if step is None:
                raise FileNotFoundError(
                    f"resume: no common verified checkpoint across the "
                    f"{B} member dirs under {rd.path(MEMBERS_DIR)}")
            dropped = 0
            for b, mrd in enumerate(mrds):
                manifest = restore_member_at(pop, b, mrd.ckpt_dir, step)
                dropped += truncate_jsonl_trace(mrd.trace_path, step)
            segment = int(manifest.get("segment", 0))
            obs.registry.counter(
                "pool_resumes_total", "checkpointed pool resumes").inc(1)
            log(f"resumed {B} members from round {step} (segment "
                f"{segment}" + (f", dropped {dropped} unreplayed trace "
                                "records" if dropped else "") + ")")

        for b, mrd in enumerate(mrds):
            pop.set_member_sink(b, JsonlSink(mrd.trace_path),
                                retain=False)

        g_loss = obs.registry.gauge(
            "pool_member_loss", "last reported loss per pool member")
        g_energy = obs.registry.gauge(
            "pool_member_energy", "cumulative energy per pool member [J]")

        def publish(status: str, **extra) -> Dict[str, Any]:
            return rd.write_state(
                status=status, pid=os.getpid(), members=B,
                scenario=pspec.base.task.kind, segment=segment,
                segment_rounds=segment_rounds,
                rounds=pop.member_rounds(0),
                energy=round(sum(pop.member_energy(b)
                                 for b in range(B)), 6), **extra)

        publish("running")
        t0 = time.monotonic()
        base_segment = segment          # max_segments counts THIS run's
        while not stopping["flag"]:     # segments, not the lifetime total
            if (max_segments is not None
                    and segment - base_segment >= max_segments):
                break
            if rd.take_request(STOP_REQ):
                break
            seg_t0 = time.monotonic()
            with obs.span("pool_segment", segment=segment + 1,
                          rounds=segment_rounds, members=B):
                pop.run_scanned(segment_rounds, eval_final=True)
                segment += 1
                with obs.span("pool_checkpoint", segment=segment) as sp:
                    total = 0
                    for b, mrd in enumerate(mrds):
                        path = save_resumable(pop.member(b), mrd.ckpt_dir,
                                              segment=segment, keep=keep)
                        try:
                            total += os.path.getsize(path)
                        except OSError:
                            pass
                    sp.attrs["bytes"] = total
                obs.on_checkpoint(sp.dur_s, total)
            rd.take_request(CKPT_REQ)   # just checkpointed: consume
            dt = time.monotonic() - seg_t0
            rps = round(B * segment_rounds / max(dt, 1e-9), 3)
            obs.registry.gauge(
                "pool_rounds_per_sec",
                "population round throughput of the last segment "
                "(members x rounds / wall-clock)").set(rps)
            for b, mrd in enumerate(mrds):
                last = (tail_jsonl(mrd.trace_path, n=1) or [{}])[-1]
                if last.get("loss") is not None:
                    g_loss.set(float(last["loss"]), pop=str(b))
                g_energy.set(pop.member_energy(b), pop=str(b))
            obs.registry.counter(
                "pool_segments_total", "pool segments completed").inc(1)
            obs.flush_snapshot()        # one metrics.jsonl record/segment
            publish("running", rounds_per_sec=rps)
            log(f"segment {segment}: round {pop.member_rounds(0)} x {B} "
                f"members, {dt:.2f}s ({rps:.1f} member-rounds/s)")
        obs.flush_snapshot()            # farewell snapshot
        state = publish("stopped",
                        wall_seconds=round(time.monotonic() - t0, 3))
        log(f"stopped after {segment} segments "
            f"({pop.member_rounds(0)} rounds x {B} members)")
        return state
    except BaseException as e:
        rd.write_state(status="failed", pid=os.getpid(),
                       error=f"{type(e).__name__}: {e}")
        raise
    finally:
        rd.clear_pid()
        for sig, handler in prev.items():
            signal.signal(sig, handler)


# --------------------------------------------------------------------- #
# status (read-only, works with or without a live process)
# --------------------------------------------------------------------- #
def pool_status(pool_dir: str, tail: int = 1) -> Dict[str, Any]:
    """Pool snapshot: serve.json + liveness + a per-member summary
    (latest verified checkpoint step, last trace record)."""
    rd = RunDir(pool_dir)
    state = rd.read_state() or {}
    pid = rd.running_pid()
    if pid is None and state.get("status") == "running":
        state["status"] = "dead"        # crashed without a farewell write
    members = []
    mroot = rd.path(MEMBERS_DIR)
    if os.path.isdir(mroot):
        for name in sorted(os.listdir(mroot)):
            mrd = RunDir(os.path.join(mroot, name))
            if not os.path.isdir(mrd.root):
                continue
            steps = [s for s, p in list_resumable(mrd.ckpt_dir)
                     if verify_checkpoint(p)]
            members.append({
                "member": name,
                "run_dir": mrd.root,
                "checkpoint_step": max(steps) if steps else None,
                "last_records": tail_jsonl(mrd.trace_path, n=tail),
            })
    return {
        "run_dir": rd.root,
        "alive": pid is not None,
        "pid": pid,
        "state": state,
        "members": members,
    }
