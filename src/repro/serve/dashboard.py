"""Terminal dashboard for ``python -m repro.serve status --watch``.

Pure text rendering over the `service_status` dict — no curses, no
dependencies: the watch loop repaints with an ANSI clear between frames
and everything here works equally on a dead run dir (the status reader
reconstructs metrics and spans from the files alone, via `tail_jsonl`).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

WIDTH = 66


def _fmt(v: Any, nd: int = 3) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.2e}"
        return f"{v:.{nd}f}".rstrip("0").rstrip(".")
    return str(v)


def _row(label: str, value: Any, label2: str = "",
         value2: Any = None) -> str:
    left = f"  {label:<18} {_fmt(value):<13}"
    if label2:
        return f"{left}{label2:<18} {_fmt(value2)}"
    return left


def _span_lines(span: Dict[str, Any], indent: int = 0,
                out: Optional[List[str]] = None) -> List[str]:
    """Indented one-line-per-node view of a span tree record."""
    if out is None:
        out = []
    dur = span.get("dur_s", 0.0)
    attrs = span.get("attrs", {})
    extra = ""
    if "dispatch_s" in attrs:           # fenced round: dispatch vs compute
        extra = (f"  (dispatch {_fmt(attrs['dispatch_s'])}s, "
                 f"device {_fmt(dur - attrs['dispatch_s'])}s)")
    elif "bytes" in attrs:
        extra = f"  ({int(attrs['bytes']):,} B)"
    out.append(f"  {'  ' * indent}{span.get('name', '?'):<{14 - 2 * indent}}"
               f" {_fmt(dur):>9}s{extra}")
    for child in span.get("children", []):
        _span_lines(child, indent + 1, out)
    return out


def render(status: Dict[str, Any]) -> str:
    """One dashboard frame from a `service_status` dict."""
    state = status.get("state") or {}
    m = status.get("metrics") or {}
    recs = status.get("last_records") or []
    last = recs[-1] if recs else {}

    live = "LIVE" if status.get("alive") else "DOWN"
    lines = ["=" * WIDTH]
    lines.append(f"  repro.serve [{live}]  {status.get('run_dir', '')}")
    lines.append(f"  status={state.get('status', '?')}"
                 f"  pid={status.get('pid') or '-'}"
                 f"  scenario={state.get('scenario', '?')}")
    lines.append("-" * WIDTH)
    lines.append(_row("rounds", state.get("rounds"),
                      "segment", state.get("segment")))
    rps = state.get("rounds_per_sec")
    if rps is None:                     # final "stopped" state omits it
        rps = m.get("service_rounds_per_sec")
    lines.append(_row("rounds/sec", rps,
                      "sim seconds", m.get("fl_sim_seconds_total")))
    lines.append(_row("loss", last.get("loss"),
                      "acc/AUC", last.get("acc")))
    lines.append(_row("energy [J]", state.get("energy"),
                      "queue deficit", m.get("fl_queue_deficit")))
    lines.append(_row("ckpt count", m.get("fl_checkpoints_total"),
                      "ckpt latency [s]",
                      m.get("fl_checkpoint_last_seconds")))
    lines.append(_row("compiles", m.get("fl_compiles_total"),
                      "compile secs", m.get("fl_compile_seconds_total")))
    lines.append(_row("fault rounds", m.get("fl_fault_rounds_total"),
                      "evals", m.get("fl_evals_total")))
    lines.append(_row("chaos kills", m.get("chaos_sigkills_total"),
                      "chaos restarts", m.get("chaos_restarts_total")))
    span = status.get("last_span")
    if span:
        lines.append("-" * WIDTH)
        lines.append("  last segment span tree:")
        lines.extend(_span_lines(span))
    if recs:
        lines.append("-" * WIDTH)
        lines.append("  recent rounds (t / cluster / a / loss):")
        for r in recs[-3:]:
            lines.append(f"    t={_fmt(r.get('t'))}"
                         f"  c={r.get('cluster')}  a={r.get('a')}"
                         f"  loss={_fmt(r.get('loss'))}")
    lines.append("=" * WIDTH)
    return "\n".join(lines)
