"""The long-running federation service: run-dir layout + segment loop.

A service instance is a directory (``--run-dir``):

    run_dir/
      spec.json        the resolved FederationSpec (config round-trip form)
      serve.json       live service state (status/pid/rounds/last metrics)
      serve.pid        pid of the running service process
      serve.log        stdout+stderr of a daemonized service
      trace.jsonl      streamed RoundRecords (one JSON object per line)
      control/         drop-box: ``stop.req`` / ``checkpoint.req`` files
      checkpoints/     ckpt_XXXXXXXX.npz + .json manifests (runner.py)

Coordination is deliberately file-based: the CLI talks to a running
service through atomically-written JSON (``serve.json``), the pidfile,
and request files the loop polls **between segments** — no sockets, no
threads next to jit.  SIGTERM/SIGINT set the same stop flag the
``stop.req`` file does, so ``kill <pid>`` and ``python -m repro.serve
stop`` both produce a final checkpoint before exit.

`run_service` is the in-process entry: build the federation from
``spec.json``, optionally adopt the newest checkpoint, stream the trace,
and loop segments until stopped or ``max_segments``.  Daemonization is
the CLI's job (`__main__.py` re-execs ``start --foreground`` under
``start_new_session``); this module never forks — forking after jax
initializes its thread pools is not safe.
"""
from __future__ import annotations

import json
import os
import signal
import time
from typing import Any, Dict, Optional

from repro.api import Federation, FederationSpec
from repro.api.records import JsonlSink, tail_jsonl
from repro.obs import (SPAN_SCHEMA, EngineObs, merge_snapshot_records)

from .runner import (SegmentRunner, latest_resumable,
                     truncate_jsonl_trace)

SPEC_FILE = "spec.json"
STATE_FILE = "serve.json"
PID_FILE = "serve.pid"
LOG_FILE = "serve.log"
TRACE_FILE = "trace.jsonl"
METRICS_FILE = "metrics.jsonl"
CONTROL_DIR = "control"
CKPT_DIR = "checkpoints"
STOP_REQ = "stop.req"
CKPT_REQ = "checkpoint.req"


# --------------------------------------------------------------------- #
# run-dir primitives
# --------------------------------------------------------------------- #
def atomic_write_json(path: str, obj: Dict[str, Any]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def read_json(path: str) -> Optional[Dict[str, Any]]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def pid_alive(pid: Optional[int]) -> bool:
    if not pid:
        return False
    try:
        os.kill(int(pid), 0)
    except (OSError, ValueError):
        return False
    return True


class RunDir:
    """Path helpers + the small file protocol of one service instance."""

    def __init__(self, root: str):
        self.root = str(root)

    def path(self, name: str) -> str:
        return os.path.join(self.root, name)

    @property
    def spec_path(self):
        return self.path(SPEC_FILE)

    @property
    def trace_path(self):
        return self.path(TRACE_FILE)

    @property
    def metrics_path(self):
        return self.path(METRICS_FILE)

    @property
    def ckpt_dir(self):
        return self.path(CKPT_DIR)

    def ensure(self) -> "RunDir":
        os.makedirs(self.path(CONTROL_DIR), exist_ok=True)
        os.makedirs(self.ckpt_dir, exist_ok=True)
        return self

    # spec ------------------------------------------------------------- #
    def write_spec(self, spec: FederationSpec) -> None:
        atomic_write_json(self.spec_path, spec.to_dict())

    def load_spec(self) -> FederationSpec:
        d = read_json(self.spec_path)
        if d is None:
            raise FileNotFoundError(
                f"{self.spec_path} missing or unreadable — is "
                f"{self.root!r} a service run dir?")
        return FederationSpec.from_dict(d)

    # state / pid ------------------------------------------------------ #
    def write_state(self, **kw) -> Dict[str, Any]:
        state = dict(kw)
        state["updated"] = time.time()
        atomic_write_json(self.path(STATE_FILE), state)
        return state

    def read_state(self) -> Optional[Dict[str, Any]]:
        return read_json(self.path(STATE_FILE))

    def write_pid(self) -> None:
        with open(self.path(PID_FILE), "w") as f:
            f.write(str(os.getpid()))

    def read_pid(self) -> Optional[int]:
        try:
            with open(self.path(PID_FILE)) as f:
                return int(f.read().strip())
        except (OSError, ValueError):
            return None

    def clear_pid(self) -> None:
        try:
            os.remove(self.path(PID_FILE))
        except OSError:
            pass

    def running_pid(self) -> Optional[int]:
        """Pid of a live service process; a stale pidfile (SIGKILLed
        daemon never reaches its ``finally`` cleanup) is removed so the
        run dir is immediately restartable."""
        pid = self.read_pid()
        if pid_alive(pid):
            return pid
        if pid is not None:
            self.clear_pid()
        return None

    # control drop-box ------------------------------------------------- #
    def request(self, name: str) -> None:
        with open(os.path.join(self.path(CONTROL_DIR), name), "w") as f:
            f.write(str(time.time()))

    def take_request(self, name: str) -> bool:
        """Consume a request file if present (one poll, between segments)."""
        try:
            os.remove(os.path.join(self.path(CONTROL_DIR), name))
        except OSError:
            return False
        return True


# --------------------------------------------------------------------- #
# the service loop
# --------------------------------------------------------------------- #
def run_service(run_dir: str, *, segment_rounds: int = 25,
                max_segments: Optional[int] = None, keep: Optional[int] = 3,
                resume: bool = False, log=print) -> Dict[str, Any]:
    """Run the segment loop in this process until stopped.

    ``resume=False`` expects an empty checkpoint dir (a fresh ``start``);
    ``resume=True`` requires one and continues from the newest checkpoint,
    first truncating ``trace.jsonl`` back to the checkpointed round so the
    continued stream equals an uninterrupted run's.  Returns the final
    service state dict.
    """
    rd = RunDir(run_dir).ensure()
    spec = rd.load_spec()

    stopping = {"flag": False}

    def _on_signal(signum, frame):
        stopping["flag"] = True

    prev = {sig: signal.signal(sig, _on_signal)
            for sig in (signal.SIGTERM, signal.SIGINT)}
    rd.write_pid()
    try:
        fed = Federation.from_spec(spec)
        runner = SegmentRunner(fed, rd.ckpt_dir,
                               segment_rounds=segment_rounds, keep=keep)
        if resume:
            manifest = runner.maybe_resume()
            if manifest is None:
                raise FileNotFoundError(
                    f"resume: no complete checkpoint under {rd.ckpt_dir}")
            dropped = truncate_jsonl_trace(rd.trace_path,
                                           manifest["rounds"])
            log(f"resumed from round {manifest['rounds']} "
                f"(segment {runner.segment}"
                + (f", dropped {dropped} unreplayed trace records"
                   if dropped else "") + ")")

        sink = JsonlSink(rd.trace_path)
        fed.engine.set_trace_sink(sink, retain=False)

        # telemetry: spans + registry snapshots stream into metrics.jsonl
        # beside the trace; the engine publishes through the same bundle
        obs = EngineObs(sink=JsonlSink(rd.metrics_path), source="service")
        fed.engine.set_obs(obs)
        runner.obs = obs
        if resume:
            obs.registry.counter(
                "service_resumes_total", "checkpointed resumes").inc(1)

        def publish(status: str, **extra) -> Dict[str, Any]:
            last = (tail_jsonl(rd.trace_path, n=1) or [None])[-1]
            return rd.write_state(
                status=status, pid=os.getpid(), scenario=spec.task.kind,
                segment=runner.segment, segment_rounds=segment_rounds,
                rounds=runner.rounds, energy=runner.energy,
                last_loss=(last or {}).get("loss"),
                last_acc=(last or {}).get("acc"), **extra)

        publish("running")
        t0 = time.monotonic()
        base_segment = runner.segment   # max_segments counts THIS run's
        while not stopping["flag"]:     # segments, not the lifetime total
            if (max_segments is not None
                    and runner.segment - base_segment >= max_segments):
                break
            if rd.take_request(STOP_REQ):
                break
            seg_t0 = time.monotonic()
            runner.run_segment()        # K rounds + checkpoint
            rd.take_request(CKPT_REQ)   # just checkpointed: consume
            dt = time.monotonic() - seg_t0
            rps = round(segment_rounds / max(dt, 1e-9), 3)
            obs.registry.gauge(
                "service_rounds_per_sec",
                "wall-clock throughput of the last segment").set(rps)
            obs.flush_snapshot()        # one metrics.jsonl record/segment
            publish("running", rounds_per_sec=rps)
            log(f"segment {runner.segment}: round {runner.rounds}, "
                f"energy {runner.energy:.1f} J, {dt:.2f}s")
        obs.flush_snapshot()            # farewell snapshot
        state = publish("stopped",
                        wall_seconds=round(time.monotonic() - t0, 3))
        log(f"stopped after {runner.segment} segments "
            f"({runner.rounds} rounds)")
        return state
    except BaseException as e:
        rd.write_state(status="failed", pid=os.getpid(),
                       error=f"{type(e).__name__}: {e}")
        raise
    finally:
        rd.clear_pid()
        for sig, handler in prev.items():
            signal.signal(sig, handler)


# --------------------------------------------------------------------- #
# status (read-only, works with or without a live process)
# --------------------------------------------------------------------- #
def load_run_metrics(run_dir: str, *, tail: int = 512
                     ) -> Optional[Dict[str, Any]]:
    """Merged last metrics snapshot of a run dir's ``metrics.jsonl``.

    Reads only the file's tail, folds the latest snapshot record of each
    source (service / chaos) into one family dict — the input both the
    Prometheus dump (`MetricsRegistry.from_snapshot`) and the dashboard
    consume.  None when the run has no metrics yet."""
    rd = RunDir(run_dir)
    return merge_snapshot_records(tail_jsonl(rd.metrics_path, n=tail))


def last_spans(run_dir: str, *, n: int = 2, tail: int = 256) -> list:
    """The last ``n`` span-tree records (schema ``span/1``) of a run
    dir's ``metrics.jsonl`` — typically the most recent segment trees."""
    rd = RunDir(run_dir)
    spans = [r for r in tail_jsonl(rd.metrics_path, n=tail)
             if r.get("schema") == SPAN_SCHEMA]
    return spans[-n:]


def service_status(run_dir: str, tail: int = 5) -> Dict[str, Any]:
    """Status snapshot: serve.json + liveness + trace tail + checkpoints
    + the telemetry summary (metric totals and the last segment's span
    tree, both read off ``metrics.jsonl`` — no live process needed)."""
    rd = RunDir(run_dir)
    state = rd.read_state() or {}
    pid = rd.running_pid()
    if pid is None and state.get("status") == "running":
        state["status"] = "dead"        # crashed without a farewell write
    latest = latest_resumable(rd.ckpt_dir)
    snap = load_run_metrics(run_dir)
    metrics: Optional[Dict[str, Any]] = None
    if snap is not None:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry.from_snapshot(snap).totals()
    spans = last_spans(run_dir, n=1)
    return {
        "run_dir": rd.root,
        "alive": pid is not None,
        "pid": pid,
        "state": state,
        "last_records": tail_jsonl(rd.trace_path, n=tail),
        "latest_checkpoint": latest[0] if latest else None,
        "checkpoint_manifest": latest[1] if latest else None,
        "metrics": metrics,
        "last_span": spans[-1] if spans else None,
    }
