"""Pallas TPU kernel: trust-weighted parameter aggregation (paper Eqn 6/19).

The aggregation hot spot of the framework: reduce C client parameter vectors
into one, weighted by normalized trust.  A naive jnp einsum sweeps HBM once
per client; this kernel streams one (C, BLOCK) tile through VMEM per grid
step and emits the weighted sum in a single pass — HBM traffic = C·N reads +
N writes, compute on the VPU, no MXU needed.

Tiling: grid over N // BLOCK; each instance holds a (C, BLOCK) tile + the
(C, 1) weight column in VMEM.  BLOCK = 8192 f32 keeps the tile ≤ C·32 KB,
comfortably inside the ~16 MB v5e VMEM for fleet sizes up to hundreds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _kernel(w_ref, x_ref, o_ref):
    # x_ref: (C, BLOCK); w_ref: (C, 1); o_ref: (BLOCK,)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (C, 1)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def trust_aggregate(params_flat, weights, *, block: int = BLOCK,
                    interpret: bool = False):
    """(C, N) x (C,) -> (N,).  N is padded to a multiple of ``block``."""
    C, N = params_flat.shape
    pad = (-N) % block
    x = jnp.pad(params_flat, ((0, 0), (0, pad))) if pad else params_flat
    Np = N + pad
    out = pl.pallas_call(
        _kernel,
        grid=(Np // block,),
        in_specs=[
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec((C, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), params_flat.dtype),
        interpret=interpret,
    )(weights[:, None], x)
    return out[:N]
