"""Pallas TPU kernel: trust-weighted parameter aggregation (paper Eqn 6/19).

The aggregation hot spot of the framework: reduce C client parameter vectors
into one, weighted by normalized trust.  A naive jnp einsum sweeps HBM once
per client; this kernel streams one (C, BLOCK) tile through VMEM per grid
step and emits the weighted sum in a single pass — HBM traffic = C·N reads +
N writes, compute on the VPU, no MXU needed.

Tiling: grid over N // BLOCK; each instance holds a (C, BLOCK) tile + the
(C, 1) weight column in VMEM.  BLOCK = 8192 f32 keeps the tile ≤ C·32 KB,
comfortably inside the ~16 MB v5e VMEM for fleet sizes up to hundreds.

The masked variant takes an extra (C,) validity column so *padded* client
rows (ragged cluster memberships run as fixed-shape grids in the fused
`FleetState` round) contribute exactly zero: the kernel multiplies the
weight column by the mask before the reduction, keeping one compiled grid
shape for every cluster regardless of its true membership count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _kernel(w_ref, x_ref, o_ref):
    # x_ref: (C, BLOCK); w_ref: (C, 1); o_ref: (BLOCK,)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (C, 1)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


def _masked_kernel(w_ref, m_ref, x_ref, o_ref):
    # identical reduction with the weight column zeroed at padded rows
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32) * m_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def trust_aggregate(params_flat, weights, mask=None, *, block: int = BLOCK,
                    interpret: bool = False):
    """(C, N) x (C,) -> (N,).  N is padded to a multiple of ``block``.

    ``mask`` (C,) marks valid client rows; None means all rows are valid
    (the dense kernel).  Masked and dense agree exactly when the masked-out
    rows carry zero weight — the kernel-equivalence property test pins it.
    """
    C, N = params_flat.shape
    pad = (-N) % block
    x = jnp.pad(params_flat, ((0, 0), (0, pad))) if pad else params_flat
    Np = N + pad
    grid = (Np // block,)
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((Np,), params_flat.dtype)
    w_spec = pl.BlockSpec((C, 1), lambda i: (0, 0))
    x_spec = pl.BlockSpec((C, block), lambda i: (0, i))
    if mask is None:
        out = pl.pallas_call(
            _kernel, grid=grid, in_specs=[w_spec, x_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(weights[:, None], x)
    else:
        out = pl.pallas_call(
            _masked_kernel, grid=grid,
            in_specs=[w_spec, pl.BlockSpec((C, 1), lambda i: (0, 0)), x_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(weights[:, None], mask.astype(jnp.float32)[:, None], x)
    return out[:N]
