"""Pallas TPU kernel: trust-weighted parameter aggregation (paper Eqn 6/19).

The aggregation hot spot of the framework: reduce C client parameter vectors
into one, weighted by normalized trust.  A naive jnp einsum sweeps HBM once
per client; this kernel streams one (C, BLOCK) tile through VMEM per grid
step and emits the weighted sum in a single pass — HBM traffic = C·N reads +
N writes, compute on the VPU, no MXU needed.

Tiling: grid over N // BLOCK; each instance holds a (C, BLOCK) tile + the
(C, 1) weight column in VMEM.  BLOCK = 8192 f32 keeps the tile ≤ C·32 KB,
comfortably inside the ~16 MB v5e VMEM for fleet sizes up to hundreds.

The masked variant takes an extra (C,) validity column so *padded* client
rows (ragged cluster memberships run as fixed-shape grids in the fused
`FleetState` round) contribute exactly zero: the kernel multiplies the
weight column by the mask before the reduction, keeping one compiled grid
shape for every cluster regardless of its true membership count.

``trust_aggregate_global`` extends the grid with the cluster batch dim the
engine's aggregation path needs: each (B + C, BLOCK) step reduces the C
member updates of the round's cluster (Eqn 6) *and* substitutes the result
into the (B, BLOCK) stacked-cluster tile for the Eqn-19 staleness-weighted
global average — one VMEM pass instead of kernel + jnp re-read, and the
unit the placement layer partitions per shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _kernel(w_ref, x_ref, o_ref):
    # x_ref: (C, BLOCK); w_ref: (C, 1); o_ref: (BLOCK,)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # (C, 1)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


def _masked_kernel(w_ref, m_ref, x_ref, o_ref):
    # identical reduction with the weight column zeroed at padded rows
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32) * m_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * w, axis=0).astype(o_ref.dtype)


def _global_kernel(c_ref, w_ref, m_ref, gw_ref, x_ref, s_ref, o_ref):
    # x_ref: (C, BLOCK) member updates; s_ref: (B, BLOCK) cluster stack;
    # w_ref/m_ref: (C, 1) weights/mask; gw_ref: (B, 1) Eqn-19 staleness
    # weights; c_ref: (1, 1) i32 index of the cluster being updated (a
    # data-dependent operand — scalar-prefetch SMEM on a real TPU).
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32) * m_ref[...].astype(jnp.float32)
    agg = jnp.sum(x * w, axis=0)                       # Eqn 6, (BLOCK,)
    s = s_ref[...].astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (s.shape[0], 1), 0)
    s = jnp.where(rows == c_ref[0, 0], agg[None, :], s)
    gw = gw_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(s * gw, axis=0).astype(o_ref.dtype)  # Eqn 19


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def trust_aggregate(params_flat, weights, mask=None, *, block: int = BLOCK,
                    interpret: bool = False):
    """(C, N) x (C,) -> (N,).  N is padded to a multiple of ``block``.

    ``mask`` (C,) marks valid client rows; None means all rows are valid
    (the dense kernel).  Masked and dense agree exactly when the masked-out
    rows carry zero weight — the kernel-equivalence property test pins it.
    """
    C, N = params_flat.shape
    pad = (-N) % block
    x = jnp.pad(params_flat, ((0, 0), (0, pad))) if pad else params_flat
    Np = N + pad
    grid = (Np // block,)
    out_spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((Np,), params_flat.dtype)
    w_spec = pl.BlockSpec((C, 1), lambda i: (0, 0))
    x_spec = pl.BlockSpec((C, block), lambda i: (0, i))
    if mask is None:
        out = pl.pallas_call(
            _kernel, grid=grid, in_specs=[w_spec, x_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(weights[:, None], x)
    else:
        out = pl.pallas_call(
            _masked_kernel, grid=grid,
            in_specs=[w_spec, pl.BlockSpec((C, 1), lambda i: (0, 0)), x_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(weights[:, None], mask.astype(jnp.float32)[:, None], x)
    return out[:N]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def trust_aggregate_global(updates_flat, weights, mask, stack_flat,
                           global_weights, c, *, block: int = BLOCK,
                           interpret: bool = False):
    """Fused Eqn 6 + Eqn 19: member updates -> the post-round global model.

    (C, N) member updates with (C,) weights/mask reduce to the round
    cluster's aggregate, which replaces row ``c`` of the (B, N) stacked
    cluster parameters before the (B,) staleness-weighted global average —
    all inside one grid pass over N.  Returns the (N,) global vector (the
    async-pull engine writes it back to both ``global_params`` and row
    ``c`` of the stack, so the intermediate Eqn-6 aggregate never
    round-trips through HBM).
    """
    C, N = updates_flat.shape
    B, Ns = stack_flat.shape
    assert Ns == N, (Ns, N)
    pad = (-N) % block
    if pad:
        updates_flat = jnp.pad(updates_flat, ((0, 0), (0, pad)))
        stack_flat = jnp.pad(stack_flat, ((0, 0), (0, pad)))
    Np = N + pad
    col = lambda r: pl.BlockSpec((r, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _global_kernel, grid=(Np // block,),
        in_specs=[col(1), col(C), col(C), col(B),
                  pl.BlockSpec((C, block), lambda i: (0, i)),
                  pl.BlockSpec((B, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), stack_flat.dtype),
        interpret=interpret,
    )(jnp.asarray(c, jnp.int32).reshape(1, 1), weights[:, None],
      mask.astype(jnp.float32)[:, None], global_weights[:, None],
      updates_flat, stack_flat)
    return out[:N]
