"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function is the mathematical definition, written with no tiling or
VMEM concerns; tests sweep shapes/dtypes and assert kernels match these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trust_aggregate_ref(params_flat, weights):
    """Eqn 6: (C, N) x (C,) -> (N,)  trust-weighted parameter average."""
    w = weights.astype(jnp.float32)
    return jnp.einsum("cn,c->n", params_flat.astype(jnp.float32), w).astype(
        params_flat.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """(B,S,H,d) x (B,S,H,d) x (B,S,H,dv) -> (B,S,H,dv), causal softmax
    attention with optional sliding window and tanh logit cap."""
    B, S, H, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -2.0e38)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(q.dtype)


def selective_scan_ref(xc, dt, Bc, Cc, A):
    """Mamba-1 recurrence.
    xc,dt: (B,S,Di); Bc,Cc: (B,S,N); A: (Di,N) -> y (B,S,Di), h (B,Di,N)."""
    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None].astype(jnp.float32) * A)
        dBx = (dt_t * xc_t)[..., None].astype(jnp.float32) * \
            B_t[:, None, :].astype(jnp.float32)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
        return h, y

    B, S, Di = xc.shape
    N = A.shape[1]
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    xs = (xc.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bc.swapaxes(0, 1), Cc.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(xc.dtype), h


def rglru_scan_ref(a, bx):
    """Gated linear recurrence h_t = a_t * h_{t-1} + bx_t.
    a, bx: (B,S,W) -> hs (B,S,W), h_last (B,W)."""
    def step(h, inp):
        a_t, bx_t = inp
        h = a_t.astype(jnp.float32) * h + bx_t.astype(jnp.float32)
        return h, h

    B, S, W = a.shape
    h0 = jnp.zeros((B, W), jnp.float32)
    h, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(a.dtype), h
