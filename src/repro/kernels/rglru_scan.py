"""Pallas TPU kernel: RG-LRU gated linear recurrence (recurrentgemma-2b).

h_t = a_t * h_{t-1} + bx_t, elementwise over the LRU width.  Channels tile
over the grid; the (BW,) state stays in VMEM across the sequence walk.
Gates a/bx are precomputed by the surrounding block (they are dense matmuls
that belong on the MXU via XLA); the kernel is the serial dependency only.

Grid: (B, W // BW).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(a_ref, bx_ref, y_ref, hout_ref, h_ref, *, seq_len: int):
    h_ref[...] = jnp.zeros_like(h_ref)                 # (1, BW) fp32

    def step(t, _):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        bx_t = bx_ref[0, t, :].astype(jnp.float32)
        h = a_t * h_ref[0] + bx_t
        h_ref[0] = h
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, seq_len, step, ())
    hout_ref[0] = h_ref[0]


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def rglru_scan(a, bx, *, bw: int = 1024, interpret: bool = False):
    """a, bx: (B,S,W) -> (hs (B,S,W), h_last (B,W))."""
    B, S, W = a.shape
    bw = min(bw, W)
    assert W % bw == 0, (W, bw)
    kernel = functools.partial(_kernel, seq_len=S)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, W // bw),
        in_specs=[
            pl.BlockSpec((1, S, bw), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, S, bw), lambda b, i: (b, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, bw), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, bw), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), a.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a, bx)
    return y, h
