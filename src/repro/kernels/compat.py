"""Pallas-TPU API compatibility across jax versions.

jax 0.4.x exposes ``pltpu.TPUCompilerParams``; newer releases renamed it to
``pltpu.CompilerParams``.  Resolve once here so every kernel works on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
