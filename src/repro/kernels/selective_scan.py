"""Pallas TPU kernel: Mamba-1 selective scan (falcon-mamba-7b hot loop).

TPU adaptation of the CUDA selective-scan: instead of warp-level parallel
prefix sums, channels are tiled over the grid — each kernel instance owns a
(BD,) slice of d_inner for one batch element, keeps its (BD, N) state
resident in VMEM, and walks the sequence with a fori_loop.  HBM traffic is
one linear sweep over the (S, BD) inputs/outputs; the O(S·BD·N) state
updates never leave VMEM (the jnp fallback materializes (B,S,Di,N)-shaped
intermediates in HBM on the backward path).

Grid: (B, Di // BD); BD = 512 keeps state + per-step operands << VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(xc_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_ref, *,
            seq_len: int):
    # xc,dt: (1, S, BD); b,c: (1, S, N); a: (BD, N); y: (1, S, BD)
    h_ref[...] = jnp.zeros_like(h_ref)                 # (BD, N) fp32
    A = a_ref[...].astype(jnp.float32)

    def step(t, _):
        xc_t = xc_ref[0, t, :].astype(jnp.float32)     # (BD,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)     # (BD,)
        B_t = b_ref[0, t, :].astype(jnp.float32)       # (N,)
        C_t = c_ref[0, t, :].astype(jnp.float32)       # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                # (BD, N)
        h = dA * h_ref[...] + (dt_t * xc_t)[:, None] * B_t[None, :]
        h_ref[...] = h
        y_ref[0, t, :] = (h @ C_t).astype(y_ref.dtype)  # (BD,)
        return ()

    jax.lax.fori_loop(0, seq_len, step, ())
    hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def selective_scan(xc, dt, Bc, Cc, A, *, bd: int = 512,
                   interpret: bool = False):
    """xc,dt: (B,S,Di); Bc,Cc: (B,S,N); A: (Di,N)
    -> (y (B,S,Di), h_last (B,Di,N))."""
    B, S, Di = xc.shape
    N = A.shape[1]
    bd = min(bd, Di)
    assert Di % bd == 0, (Di, bd)
    kernel = functools.partial(_kernel, seq_len=S)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, Di // bd),
        in_specs=[
            pl.BlockSpec((1, S, bd), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, S, bd), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, N), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((bd, N), lambda b, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, S, bd), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, bd, N), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), xc.dtype),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xc, dt, Bc, Cc, A)
    return y, h
