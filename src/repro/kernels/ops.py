"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel body in Python for correctness validation);
on a real TPU pass interpret=False and the same BlockSpecs compile to
Mosaic.  ``INTERPRET`` flips the default globally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .rglru_scan import rglru_scan
from .selective_scan import selective_scan
from .trust_aggregate import trust_aggregate, trust_aggregate_global

INTERPRET = jax.default_backend() == "cpu"


def _flatten_rows(tree):
    leaves, treedef = jax.tree.flatten(tree)
    C = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.reshape(C, -1).astype(jnp.float32) for x in leaves], axis=1)
    return flat, leaves, treedef


def _unflatten_row(vec, leaves, treedef):
    out, off = [], 0
    for x in leaves:
        n = x[0].size
        out.append(vec[off:off + n].reshape(x.shape[1:]).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def trust_aggregate_tree(client_params, weights, mask=None, *,
                         interpret=None):
    """Eqn 6 over a pytree with leading client dim, via the Pallas kernel.
    ``mask`` (C,) selects valid rows (padded fixed-shape cluster rounds)."""
    interpret = INTERPRET if interpret is None else interpret
    flat, leaves, treedef = _flatten_rows(client_params)
    agg = trust_aggregate(flat, weights, mask, interpret=interpret)
    return _unflatten_row(agg, leaves, treedef)


def trust_aggregate_global_tree(client_params, weights, mask, cluster_stack,
                                global_weights, c, *, interpret=None):
    """Fused Eqn 6 + Eqn 19 over pytrees: member updates (leading dim C)
    plus the stacked cluster parameters (leading dim n_clusters) -> the
    staleness-weighted global model, in one kernel pass.  ``c`` is the
    (traced) cluster whose Eqn-6 aggregate replaces its stack row."""
    interpret = INTERPRET if interpret is None else interpret
    upd_flat, _, _ = _flatten_rows(client_params)
    stack_flat, leaves, treedef = _flatten_rows(cluster_stack)
    glob = trust_aggregate_global(upd_flat, weights, mask, stack_flat,
                                  global_weights, c, interpret=interpret)
    return _unflatten_row(glob, leaves, treedef)


def attention(q, k, v, *, window=0, softcap=0.0, bq=256, bk=256,
              interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return flash_attention(q, k, v, window=window, softcap=softcap,
                           bq=bq, bk=bk, interpret=interpret)


def mamba_scan(xc, dt, Bc, Cc, A, *, bd=512, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return selective_scan(xc, dt, Bc, Cc, A, bd=bd, interpret=interpret)


def lru_scan(a, bx, *, bw=1024, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return rglru_scan(a, bx, bw=bw, interpret=interpret)
