"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel body in Python for correctness validation);
on a real TPU pass interpret=False and the same BlockSpecs compile to
Mosaic.  ``INTERPRET`` flips the default globally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .rglru_scan import rglru_scan
from .selective_scan import selective_scan
from .trust_aggregate import trust_aggregate

INTERPRET = jax.default_backend() == "cpu"


def trust_aggregate_tree(client_params, weights, mask=None, *,
                         interpret=None):
    """Eqn 6 over a pytree with leading client dim, via the Pallas kernel.
    ``mask`` (C,) selects valid rows (padded fixed-shape cluster rounds)."""
    interpret = INTERPRET if interpret is None else interpret
    leaves, treedef = jax.tree.flatten(client_params)
    C = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.reshape(C, -1).astype(jnp.float32) for x in leaves], axis=1)
    agg = trust_aggregate(flat, weights, mask, interpret=interpret)
    out, off = [], 0
    for x in leaves:
        n = x[0].size
        out.append(agg[off:off + n].reshape(x.shape[1:]).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def attention(q, k, v, *, window=0, softcap=0.0, bq=256, bk=256,
              interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return flash_attention(q, k, v, window=window, softcap=softcap,
                           bq=bq, bk=bk, interpret=interpret)


def mamba_scan(xc, dt, Bc, Cc, A, *, bd=512, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return selective_scan(xc, dt, Bc, Cc, A, bd=bd, interpret=interpret)


def lru_scan(a, bx, *, bw=1024, interpret=None):
    interpret = INTERPRET if interpret is None else interpret
    return rglru_scan(a, bx, bw=bw, interpret=interpret)
