"""Pallas TPU kernel: blockwise causal flash attention (online softmax).

Serves prefill_32k (quadratic scores never hit HBM) and the sliding-window
long-context variant.  TPU-native design: the MXU consumes (BQ, d) x (d, BK)
tiles; running max/sum/accumulator live in VMEM scratch that persists across
the minormost (arbitrary-semantics) KV grid dimension.

Grid: (B*H, S//BQ, S//BK), KV innermost.  Causal + window block skipping via
pl.when — fully-masked KV blocks are never computed (a 2x FLOP saving for
causal, ~S/window x for sliding windows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, scale: float, window: int, softcap: float,
            n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level causal/window reachability
    reachable = k_start <= q_start + bq - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, k_start + bk - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (BQ, d)
        k = k_ref[0].astype(jnp.float32)              # (BK, d)
        v = v_ref[0].astype(jnp.float32)              # (BK, dv)
        s = (q @ k.T) * scale                         # (BQ, BK)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)               # (BQ, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bq", "bk", "window", "softcap", "interpret"))
def flash_attention(q, k, v, *, bq: int = 256, bk: int = 256,
                    window: int = 0, softcap: float = 0.0,
                    interpret: bool = False):
    """q,k: (B,S,H,d), v: (B,S,H,dv) -> (B,S,H,dv); causal (+window).

    H folds into the leading grid dim; within a (B*H) slice the kernel walks
    KV blocks with online softmax.  GQA callers repeat K/V heads first.
    """
    B, S, H, d = q.shape
    dv = v.shape[-1]
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = d ** -0.5
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, x.shape[-1])
    qf, kf, vf = fold(q), fold(k), fold(v)
    n_k = S // bk

    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                               window=window, softcap=softcap, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running sum l
            pltpu.VMEM((bq, dv), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
