from .ops import attention, lru_scan, mamba_scan, trust_aggregate_tree
from .trust_aggregate import trust_aggregate
from .flash_attention import flash_attention
from .selective_scan import selective_scan
from .rglru_scan import rglru_scan

__all__ = ["attention", "lru_scan", "mamba_scan", "trust_aggregate_tree",
           "trust_aggregate", "flash_attention", "selective_scan",
           "rglru_scan"]
