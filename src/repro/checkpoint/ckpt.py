"""Pytree checkpointing to .npz (offline container — no orbax).

Leaves are flattened with '/'-joined key paths; structure and dtypes round-trip
exactly.  Device arrays are fetched host-side before serialization, so this
works for sharded trees too (gathers — intended for the example-scale models;
production sharded checkpointing would write per-shard files, noted in
DESIGN.md as out of scope for the CPU container).
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


_BF16 = "__bf16__:"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16 codec: store as f32 with a dtype marker
            flat[_BF16 + key] = arr.astype(np.float32)
        else:
            flat[key] = arr
    return flat


def save_checkpoint(path: str, step: int, tree: Any) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    np.savez(fname, **_flatten(tree))
    return fname


def load_checkpoint(fname: str, like: Any) -> Any:
    with np.load(fname) as data:
        flat = {k: data[k] for k in data.files}
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if _BF16 + key in flat:
            arr = flat[_BF16 + key].astype(jnp.bfloat16)
        else:
            arr = flat[key]
        leaves.append(jnp.asarray(
            arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(path: str) -> str | None:
    if not os.path.isdir(path):
        return None
    files = [f for f in os.listdir(path) if re.match(r"ckpt_\d+\.npz$", f)]
    if not files:
        return None
    return os.path.join(path, sorted(files)[-1])
