"""Pytree checkpointing to .npz (offline container — no orbax).

Leaves are flattened with '/'-joined key paths; structure and dtypes round-trip
exactly.  Device arrays are fetched host-side before serialization, so this
works for sharded trees too (gathers — intended for the example-scale models;
production sharded checkpointing would write per-shard files, noted in
DESIGN.md as out of scope for the CPU container).

Two leaf kinds need a dtype marker because npz has no native codec for them:

* bfloat16 leaves store as f32 under a ``__bf16__:`` key prefix;
* typed JAX PRNG keys (``jax.random.key``-style, extended dtypes the service
  layer checkpoints as part of a resumable `FleetState`) store their raw
  ``jax.random.key_data`` under ``__key__:<impl>:`` and are rebuilt with
  ``jax.random.wrap_key_data`` on load, so the restored key continues the
  exact random stream.  Raw ``PRNGKey`` uint32 arrays need no marker.

Writes are crash-safe: the archive lands under a ``.tmp`` name and is
``os.replace``-d into place, so a reader (or a resume after a mid-write
crash) never sees a torn checkpoint file.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


_BF16 = "__bf16__:"
_KEY = "__key__:"


def _is_typed_key(leaf) -> bool:
    return (hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jax.dtypes.prng_key))


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if _is_typed_key(leaf):
            # typed PRNG keys have an extended dtype npz cannot store:
            # keep the raw counter words plus the impl name in the marker
            impl = str(jax.random.key_impl(leaf))
            flat[f"{_KEY}{impl}:{key}"] = np.asarray(
                jax.random.key_data(leaf))
            continue
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16 codec: store as f32 with a dtype marker
            flat[_BF16 + key] = arr.astype(np.float32)
        else:
            flat[key] = arr
    return flat


def save_checkpoint(path: str, step: int, tree: Any) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    tmp = fname + ".tmp"
    # write to a sibling temp file and rename into place: os.replace is
    # atomic on POSIX, so a crash mid-write leaves only the .tmp orphan
    # (ignored by latest_checkpoint) and never a truncated .npz
    with open(tmp, "wb") as f:
        np.savez(f, **_flatten(tree))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, fname)
    return fname


def load_checkpoint(fname: str, like: Any) -> Any:
    with np.load(fname) as data:
        flat = {k: data[k] for k in data.files}
    entries = {}
    for k, arr in flat.items():
        if k.startswith(_BF16):
            entries[k[len(_BF16):]] = ("bf16", None, arr)
        elif k.startswith(_KEY):
            impl, path = k[len(_KEY):].split(":", 1)
            entries[path] = ("key", impl, arr)
        else:
            entries[k] = ("raw", None, arr)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        kind, impl, arr = entries[key]
        if kind == "key":
            leaves.append(jax.random.wrap_key_data(jnp.asarray(arr),
                                                   impl=impl))
        elif kind == "bf16":
            leaves.append(jnp.asarray(arr.astype(jnp.bfloat16)))
        else:
            leaves.append(jnp.asarray(
                arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_checkpoint(path: str) -> str | None:
    if not os.path.isdir(path):
        return None
    files = [f for f in os.listdir(path) if re.match(r"ckpt_\d+\.npz$", f)]
    if not files:
        return None
    return os.path.join(path, sorted(files)[-1])
