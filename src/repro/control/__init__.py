"""The in-jit control plane (paper §IV: Lyapunov queue + DQN, Alg. 1).

The paper's core contribution is *adaptive* aggregation frequency, yet the
controller was the last host-side component of the engine: every adaptive
round paid a device→host context pull before ``select``.  This package
makes frequency control a device-resident subsystem with one functional
interface — ``step(state, CtlObs) -> (action, state)`` — that traces
inside the fused round:

  queue         Eqn-12 deficit queue as a `FleetState` array leaf,
                advanced in-jit with the realized consumption
  policy        `ScanPolicy` implementations: fixed, Lyapunov greedy
                (Eqn 15), DQN greedy head, and a distilled lookup table
  scanned_dqn   Alg. 1 training lowered into nested `lax.scan` over the
                DT-simulated environment (replaces the host episode loop)

`DeviceScaleEngine.run_scanned(K)` consumes these to lower K whole rounds
— controller included — into a single `lax.scan`; see API.md's
"Control plane" section.
"""
from .policy import (CtlObs, PolicyTable, ScanPolicy, distill_table,
                     dqn_policy, fixed_policy, lyapunov_policy,
                     lyapunov_scores, table_policy)
from .queue import advance as queue_advance_leaf
from .queue import init_leaf as queue_init_leaf
from .queue import per_slot_of
from .scanned_dqn import episode_step, train_on_env

__all__ = [
    "CtlObs", "ScanPolicy", "PolicyTable",
    "fixed_policy", "lyapunov_policy", "lyapunov_scores", "dqn_policy",
    "distill_table", "table_policy",
    "queue_init_leaf", "queue_advance_leaf", "per_slot_of",
    "train_on_env", "episode_step",
]
