"""Device-resident Lyapunov deficit queue (paper §IV-A, Eqns 12-15).

The event-heap engine kept the Eqn-12 backlog inside the host-side
`LyapunovGreedyController` object, advanced from a pulled ``consumed``
scalar every round — the last per-round device→host dependency of adaptive
runs.  This module moves the queue into `FleetState` as a plain f32 array
leaf: `init_leaf` seeds it, the fused round advances it **in-jit** with the
realized consumption via `core.lyapunov.queue_advance` (one canonical
Eqn-12 formula for both the host and the scanned paths), and the in-jit
controllers in `repro.control.policy` read it straight off the state.

``per_slot_of`` extracts the replenishment rate beta·R_m/k from whatever
controller drives the engine: controllers without a resource budget (fixed,
DQN) report +inf, which pins the queue at 0 — the queue leaf then exists in
every `FleetState` without changing non-Lyapunov dynamics.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.lyapunov import queue_advance

__all__ = ["init_leaf", "advance", "per_slot_of", "queue_advance"]

NO_BUDGET = float("inf")        # per-slot replenishment that pins q at 0


def init_leaf(value: float = 0.0) -> jnp.ndarray:
    """The FleetState queue leaf: a scalar f32 backlog."""
    return jnp.asarray(value, jnp.float32)


def advance(q, consumed, per_slot: float):
    """Eqn 12, jit/scan-safe: q' = max(q + consumed - per_slot, 0)."""
    return queue_advance(q, consumed, per_slot)


def per_slot_of(controller) -> float:
    """Replenishment rate of a controller's deficit queue, +inf if it has
    none (max(q + e - inf, 0) == 0, so budgetless controllers keep q = 0)."""
    dq = getattr(controller, "queue", None)
    if dq is None:
        return NO_BUDGET
    return float(dq.per_slot)
