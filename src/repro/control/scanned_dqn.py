"""Alg. 1 (DQN on the DT-simulated env) as pure scannable steps.

`DQNController.pretrain` used to drive the §IV-C environment with a Python
``while not done`` loop — one `select_action` + `envs.step` + `store` +
`train_step` dispatch chain per transition, hundreds of host round-trips
per training run.  This module lowers whole episodes into **nested
`lax.scan`**: the inner scan runs a fixed ``horizon`` of environment steps
(episodes that terminate early — budget exhaustion — freeze their carry so
the trailing steps are no-ops on exactly the state a host loop would have
stopped at), the outer scan folds episodes, and the entire training run
compiles to a single XLA program.

The building blocks are the existing pure pieces of `repro.core.dqn`: the
fixed-size ring-buffer `Replay` pytree (`store` wraps the write pointer
in-jit), the epsilon schedule driven by the traced step counter (`epsilon`),
and the periodic target sync inside `train_step_fn` (``step % target_sync``
on a traced scalar) — none of them needed to change to become scan legs.

``scan=False`` runs the *identical* step function in a Python loop (same
key splits, same freeze semantics) — the eager reference the parity test
pins the lowered program against.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dqn as dqn_lib
from repro.core import envs

__all__ = ["train_on_env", "episode_step"]


class _EpCarry(NamedTuple):
    key: jnp.ndarray
    env: envs.EnvState
    obs: jnp.ndarray
    done: jnp.ndarray           # () bool: episode already terminated
    agent: dqn_lib.DQNState
    ret: jnp.ndarray            # () f32 undiscounted episode return


def _freeze(done, new, old):
    """Select ``old`` wherever the episode has already terminated, so the
    fixed-length scan is a bitwise no-op past the terminal transition."""
    return jax.tree.map(lambda n, o: jnp.where(done, o, n), new, old)


def episode_step(carry: _EpCarry, cfg: dqn_lib.DQNConfig,
                 p: envs.EnvParams) -> _EpCarry:
    """One Alg.-1 transition: epsilon-greedy select, env step, replay store,
    TD train.  Pure — usable as a `lax.scan` leg or in a host loop."""
    key, ka, kt = jax.random.split(carry.key, 3)
    a = dqn_lib.select_action(ka, carry.agent, cfg, carry.obs)
    env, obs2, r, done2, _ = envs.step(carry.env, a, p)
    agent = dqn_lib.store(carry.agent, carry.obs, a, r, obs2)
    agent, _ = dqn_lib.train_step_fn(kt, agent, cfg)
    new = _EpCarry(key=key, env=env, obs=obs2, done=carry.done | done2,
                   agent=agent, ret=carry.ret + r)
    return _freeze(carry.done, new, carry)


def train_on_env(key, agent: dqn_lib.DQNState, cfg: dqn_lib.DQNConfig,
                 p: envs.EnvParams, *, episodes: int,
                 scan: bool = True) -> tuple:
    """Train ``agent`` for ``episodes`` episodes of the DT env (Alg. 1).

    Returns ``(agent, aux)`` with ``aux = {"ep_return": (episodes,),
    "ep_len": (episodes,)}``.  ``scan=True`` lowers the whole run into one
    jit-compiled nested `lax.scan` (episodes × ``p.horizon`` steps);
    ``scan=False`` executes the same `episode_step` eagerly from Python —
    the two are trace-identical at a fixed key
    (tests/test_control.py::test_scanned_dqn_matches_eager).
    """
    def run_episode(key, agent, ep):
        env, obs = envs.reset(jax.random.fold_in(key, ep), p)
        carry = _EpCarry(key=key, env=env, obs=obs,
                         done=jnp.zeros((), bool), agent=agent,
                         ret=jnp.zeros((), jnp.float32))
        if scan:
            carry = jax.lax.scan(
                lambda c, _: (episode_step(c, cfg, p), None),
                carry, None, length=p.horizon)[0]
        else:
            for _ in range(p.horizon):
                carry = episode_step(carry, cfg, p)
        ep_len = jnp.where(carry.done, carry.env.round,
                           jnp.asarray(p.horizon, jnp.int32))
        return carry.key, carry.agent, carry.ret, ep_len

    if scan:
        def ep_body(carry, ep):
            key, agent = carry
            key, agent, ret, ep_len = run_episode(key, agent, ep)
            return (key, agent), {"ep_return": ret, "ep_len": ep_len}

        (key, agent), aux = jax.jit(
            lambda k, ag: jax.lax.scan(ep_body, (k, ag),
                                       jnp.arange(episodes)))(key, agent)
        return agent, aux

    rets, lens = [], []
    for ep in range(episodes):
        key, agent, ret, ep_len = run_episode(key, agent, ep)
        rets.append(ret)
        lens.append(ep_len)
    return agent, {"ep_return": jnp.stack(rets), "ep_len": jnp.stack(lens)}
