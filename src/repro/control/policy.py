"""In-jit frequency-control policies: `(state, obs) -> (action, state)`.

The uniform functional interface of the control plane.  A `ScanPolicy` is a
pure step function plus its initial carry; the engine traces it *inside*
the fused round (`DeviceScaleEngine.run_scanned`), so a policy body must be
jnp-only — no host syncs, no Python control flow on traced values.  The
host-side controller classes in `repro.api.components` wrap the same
functions for the event-heap path, so both execution modes score actions
with identical device math.

Policies
  fixed_policy      constant raw a_i (the Alg.-2 bound still applies in the
                    round itself)
  lyapunov_policy   Eqn-15 drift-plus-penalty argmax over a ∈ {1..n};
                    reads the Eqn-12 deficit queue straight off the
                    `FleetState.queue` leaf via `CtlObs.queue`
  dqn_policy        greedy head of a trained Alg.-1 DQN on the 48-dim
                    observation
  table_policy      a distilled lookup table (`distill_table`) — argmax
                    resolved at distillation time, selects are three
                    bucketizes and one gather
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dqn import q_values
from repro.core.energy import compute_energy
from repro.core.envs import OBS_DIM
from repro.core.lyapunov import v_schedule

__all__ = ["CtlObs", "ScanPolicy", "fixed_policy", "lyapunov_policy",
           "lyapunov_scores", "dqn_policy", "deploy_obs", "distill_table",
           "table_policy", "PolicyTable"]


class CtlObs(NamedTuple):
    """What an in-jit policy sees each round — all traced scalars except
    ``dqn_obs``, which the engine materializes only for ``needs_obs``
    policies (zeros otherwise)."""
    round: jnp.ndarray              # () i32 global round counter
    cluster: jnp.ndarray            # () i32 cluster being scheduled
    queue: jnp.ndarray              # () f32 Eqn-12 deficit backlog
    cluster_loss: jnp.ndarray       # () f32 masked mean twin loss
    cluster_freq: jnp.ndarray       # () f32 straggler calibrated frequency
    mean_freq: jnp.ndarray          # () f32 mean calibrated frequency
    channel_good_frac: jnp.ndarray  # () f32 members in the good state
    energy_used: jnp.ndarray        # () f32 running energy tally
    dqn_obs: jnp.ndarray            # (OBS_DIM,) f32 §IV-B observation


class ScanPolicy(NamedTuple):
    """A scannable controller: pure ``step(state, CtlObs) -> (a_raw, state)``
    plus the initial carry.  ``needs_obs`` tells the engine whether to build
    the 48-dim DQN observation (a matmul's worth of work) each round."""
    state: Any
    step: Callable[[Any, CtlObs], tuple]
    needs_obs: bool = False


# --------------------------------------------------------------------- #
# fixed
# --------------------------------------------------------------------- #
def fixed_policy(a: int) -> ScanPolicy:
    a = jnp.asarray(int(a), jnp.int32)

    def step(state, obs: CtlObs):
        return a, state

    return ScanPolicy(state=(), step=step, needs_obs=False)


# --------------------------------------------------------------------- #
# Lyapunov drift-plus-penalty greedy (Eqns 12-15)
# --------------------------------------------------------------------- #
def lyapunov_scores(q, round_idx, loss, mean_freq, good_frac, *,
                    n_actions: int, kappa: float, f_star: float,
                    v0: float, v_growth: float) -> jnp.ndarray:
    """P2 objective of every a ∈ {1..n_actions}, Eqn 15:
    v·ΔF̂(a) − Q(i)·(a·Ê_cmp + Ê_com), vectorized over actions.

    The loss model is exponential decay toward ``f_star`` at rate ``kappa``
    per local step; the comm term uses the good-state fraction as a rate
    proxy.  Shared by the host `LyapunovGreedyController.select` and the
    in-jit `lyapunov_policy`, so both paths pick identical actions.
    """
    a = jnp.arange(1, n_actions + 1, dtype=jnp.float32)
    v = v_schedule(jnp.asarray(round_idx, jnp.float32), v0, v_growth)
    pred = f_star + (loss - f_star) * jnp.exp(-kappa * a)
    e_cmp = compute_energy(jnp.asarray(mean_freq, jnp.float32))
    e_com = e_cmp * (2.0 - good_frac)
    cost = a * e_cmp + e_com
    return v * (loss - pred) - q * cost


def lyapunov_policy(*, n_actions: int = 10, kappa: float = 0.08,
                    f_star: float = 0.1, v0: float = 1.0,
                    v_growth: float = 0.02) -> ScanPolicy:
    def step(state, obs: CtlObs):
        s = lyapunov_scores(obs.queue, obs.round, obs.cluster_loss,
                            obs.mean_freq, obs.channel_good_frac,
                            n_actions=n_actions, kappa=kappa, f_star=f_star,
                            v0=v0, v_growth=v_growth)
        return jnp.argmax(s).astype(jnp.int32) + 1, state

    return ScanPolicy(state=(), step=step, needs_obs=False)


# --------------------------------------------------------------------- #
# DQN greedy head
# --------------------------------------------------------------------- #
def dqn_policy(eval_params) -> ScanPolicy:
    # the net rides in the policy carry (a traced argument), so a compiled
    # scan is reusable across retrained agents instead of baking the
    # weights in as program constants
    def step(state, obs: CtlObs):
        q = q_values(state, obs.dqn_obs)
        return jnp.argmax(q).astype(jnp.int32) + 1, state

    return ScanPolicy(state=eval_params, step=step, needs_obs=True)


# --------------------------------------------------------------------- #
# distilled lookup table
# --------------------------------------------------------------------- #
class PolicyTable(NamedTuple):
    """Actions pre-argmaxed over a (loss × round × channel) grid."""
    table: jnp.ndarray              # (L, R, G) int32 actions in {1..n}
    loss_grid: jnp.ndarray          # (L,) f32 bin centers
    round_grid: jnp.ndarray         # (R,) f32
    good_grid: jnp.ndarray          # (G,) f32


def deploy_obs(loss, queue, round_frac, tau, round_mod, ch3, mean_freq, *,
               loss_max: float = 2.3) -> jnp.ndarray:
    """The deployment-side §IV-B observation layout, in one place.

    Slots: [loss, loss_max−loss, Eqn-12 queue, round fraction, tau,
    one_hot(round_mod, 10), channel one-hot fractions (3), mean calibrated
    frequency, 0, 0, pad to OBS_DIM].  `DeviceScaleEngine._scan_obs` fills
    it from live `FleetState`; `_grid_obs` below fills it with grid/neutral
    values for distillation — both call this builder so the slots cannot
    drift apart.  (The training env's `envs._obs` keeps its own layout;
    the engine-side deviations are documented at `_scan_obs`.)
    """
    feats = jnp.concatenate([
        jnp.stack([loss, loss_max - loss, queue, round_frac, tau]),
        jax.nn.one_hot(jnp.minimum(round_mod, 9), 10),
        ch3,
        jnp.stack([mean_freq, jnp.float32(0.0), jnp.float32(0.0)]),
    ])
    return jnp.pad(feats, (0, OBS_DIM - feats.shape[0]))


def _grid_obs(loss, round_idx, good_frac, *, loss_max: float,
              horizon: float) -> jnp.ndarray:
    """Synthesize `deploy_obs` for one grid point (queue/tau/frequency at
    their neutral values — the distillation marginal)."""
    ch3 = jnp.stack([good_frac, (1.0 - good_frac) * 0.5,
                     (1.0 - good_frac) * 0.5])
    return deploy_obs(loss, jnp.float32(0.0), round_idx / horizon,
                      jnp.tanh(loss),
                      jnp.mod(round_idx.astype(jnp.int32), 10), ch3,
                      jnp.float32(1.0), loss_max=loss_max)


def distill_table(eval_params, *, loss_bins: int = 24, round_bins: int = 16,
                  good_bins: int = 8, loss_max: float = 2.3,
                  horizon: float = 100.0) -> PolicyTable:
    """Evaluate the trained net over a feature grid and freeze the argmax.

    One batched forward pass at distillation time buys selects that are
    three bucketizes and one gather — microseconds, and embeddable anywhere
    a full matmul stack is too heavy (e.g. per-device firmware tables).
    """
    loss_grid = jnp.linspace(0.0, loss_max, loss_bins)
    round_grid = jnp.linspace(0.0, horizon, round_bins)
    good_grid = jnp.linspace(0.0, 1.0, good_bins)
    obs = jax.vmap(lambda l: jax.vmap(lambda r: jax.vmap(
        lambda g: _grid_obs(l, r, g, loss_max=loss_max, horizon=horizon)
    )(good_grid))(round_grid))(loss_grid)          # (L, R, G, OBS_DIM)
    q = q_values(eval_params, obs)                 # (L, R, G, n_actions)
    table = jnp.argmax(q, axis=-1).astype(jnp.int32) + 1
    return PolicyTable(table=table, loss_grid=loss_grid,
                       round_grid=round_grid, good_grid=good_grid)


def _nearest(grid, x):
    return jnp.clip(jnp.searchsorted(0.5 * (grid[1:] + grid[:-1]), x),
                    0, grid.shape[0] - 1)


def table_policy(table: PolicyTable) -> ScanPolicy:
    def step(state, obs: CtlObs):
        i = _nearest(table.loss_grid, obs.cluster_loss)
        j = _nearest(table.round_grid, obs.round.astype(jnp.float32))
        k = _nearest(table.good_grid, obs.channel_good_frac)
        return table.table[i, j, k], state

    return ScanPolicy(state=(), step=step, needs_obs=False)
