"""`EngineObs`: the bundle engines and the serve stack publish through.

One `EngineObs` owns a `MetricsRegistry` + a `SpanRecorder` sharing a
single JSONL sink (the run dir's ``metrics.jsonl``).  Attach it with
``engine.set_obs(obs)``; the engine then reports

* per-round aggregates the **cheap** way: the scanned path hands over
  the stacked per-round metrics it already synced once per segment (the
  deferred-host-sync design — telemetry adds no extra device round
  trips and, critically, no new scan outputs, so the compiled program
  and its traces stay bit-identical to an uninstrumented run);
* a per-segment state summary (deficit-queue level, trust-weight /
  reputation stats, Eqn-4 β tally) via one tiny *read-only* jitted
  reduction over `FleetState` — it never touches the round program;
* one-time compile events: when a scan cache miss occurs under
  telemetry, the engine lowers + compiles explicitly (AOT — the same
  executable the jit path would build), times it under a
  ``span("compile")``, and feeds the optimized HLO through
  `repro.launch.hlo_stats.analyze_module` for collective counts;
* fault bookkeeping: the `FaultModel`'s *static* tallies (Byzantine
  subset sizes, per-family rates) as gauges, plus a rounds-under-fault
  counter.  Realized in-jit draws are deliberately not counted — that
  would require new scan outputs and break trace bit-parity.

Metric names follow Prometheus conventions with an ``fl_`` prefix; the
serve supervisor adds ``service_*`` and the chaos harness ``chaos_*``
families into the same ``metrics.jsonl`` (see
`repro.obs.metrics.merge_snapshot_records`).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from .metrics import MetricsRegistry, snapshot_record
from .spans import SpanRecorder

EVENT_SCHEMA = "event/1"        # one-time event records (compiles)


class EngineObs:
    """Registry + spans + sink, with the engine-facing publish hooks."""

    def __init__(self, sink=None, registry: Optional[MetricsRegistry] = None,
                 spans: Optional[SpanRecorder] = None,
                 source: str = "service", max_series: int = 64):
        self.sink = sink
        self.source = source
        self.registry = registry if registry is not None \
            else MetricsRegistry(max_series=max_series)
        self.spans = spans if spans is not None else SpanRecorder(sink=sink)
        r = self.registry
        self.m_rounds = r.counter(
            "fl_rounds_total", "federated rounds executed")
        self.m_cluster_rounds = r.counter(
            "fl_cluster_rounds_total", "rounds per cluster")
        self.m_actions = r.counter(
            "fl_actions_total", "controller aggregation-frequency choices")
        self.m_energy = r.counter(
            "fl_energy_joules_total", "cumulative fleet energy (Eqn 9-11)")
        self.m_sim = r.counter(
            "fl_sim_seconds_total", "simulated seconds advanced")
        self.m_round_dur = r.histogram(
            "fl_round_duration_sim_seconds",
            "per-round simulated duration",
            buckets=(0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0))
        self.m_loss = r.gauge(
            "fl_train_loss", "last round's mean member training loss")
        self.m_eval_loss = r.gauge("fl_eval_loss", "last evaluation loss")
        self.m_eval_acc = r.gauge(
            "fl_eval_acc", "last evaluation accuracy / detection AUC")
        self.m_evals = r.counter("fl_evals_total", "evaluations run")
        self.m_queue = r.gauge(
            "fl_queue_deficit", "Eqn-12 virtual deficit-queue level")
        self.m_rep = r.gauge(
            "fl_reputation", "Eqn-4 trust-weight summary (label: stat)")
        self.m_beta = r.gauge(
            "fl_twin_beta_sum", "total Eqn-4 negative-interaction tally")
        self.m_compiles = r.counter(
            "fl_compiles_total", "device programs compiled")
        self.m_compile_s = r.counter(
            "fl_compile_seconds_total", "wall seconds spent compiling")
        self.m_hlo_coll = r.gauge(
            "fl_hlo_collective_ops", "collective op count in optimized HLO")
        self.m_hlo_flops = r.gauge(
            "fl_hlo_flops", "estimated FLOPs of the compiled program")
        self.m_ckpts = r.counter("fl_checkpoints_total", "checkpoints taken")
        self.m_ckpt_s = r.histogram(
            "fl_checkpoint_seconds", "checkpoint wall-clock latency")
        self.m_ckpt_last = r.gauge(
            "fl_checkpoint_last_seconds", "latency of the last checkpoint")
        self.m_ckpt_bytes = r.gauge(
            "fl_checkpoint_bytes", "size of the last checkpoint")
        self.m_fault_rounds = r.counter(
            "fl_fault_rounds_total", "rounds run under an active FaultSpec")

    # ------------------------------------------------------------------ #
    def span(self, name: str, fence_on=None, **attrs):
        return self.spans.span(name, fence_on=fence_on, **attrs)

    def flush_snapshot(self) -> None:
        """Append a registry snapshot record to the sink (the serve loop
        calls this once per segment; chaos after each kill/restart)."""
        if self.sink is not None:
            self.sink.append(snapshot_record(
                self.registry, source=self.source, ts=time.time()))

    # engine-facing hooks ---------------------------------------------- #
    def publish_static(self, engine) -> None:
        """One-time gauges at attach: fleet shape + fault-model statics."""
        r = self.registry
        spec = engine.spec
        r.gauge("fl_devices", "fleet size").set(spec.fleet.n_devices)
        r.gauge("fl_clusters", "cluster count").set(
            spec.clustering.n_clusters)
        fm = getattr(engine, "faults", None)
        if fm is not None:
            for k, v in fm.stats().items():
                r.gauge(f"fl_fault_{k}", "FaultModel static bookkeeping"
                        ).set(float(v))

    def on_segment(self, ys, K: int, engine=None) -> None:
        """Fold one scan segment's stacked host metrics into the registry.

        ``ys`` is the already-synced host dict (t/cluster/a/dur/consumed/
        loss, each (K,)) — the same arrays the trace records are built
        from, so this costs numpy over K scalars and nothing device-side.
        """
        self.m_rounds.inc(K)
        cl = np.asarray(ys["cluster"]).astype(np.int64)
        for c, n in zip(*np.unique(cl, return_counts=True)):
            self.m_cluster_rounds.inc(float(n), cluster=str(int(c)))
        av = np.asarray(ys["a"]).astype(np.int64)
        for a, n in zip(*np.unique(av, return_counts=True)):
            self.m_actions.inc(float(n), a=str(int(a)))
        dur = np.asarray(ys["dur"], np.float64)
        self.m_energy.inc(float(np.sum(np.asarray(ys["consumed"],
                                                  np.float64))))
        self.m_sim.inc(float(np.sum(dur)))
        for d in dur:
            self.m_round_dur.observe(float(d))
        self.m_loss.set(float(np.asarray(ys["loss"])[-1]))
        if engine is not None:
            fm = getattr(engine, "faults", None)
            if fm is not None and fm.active:
                self.m_fault_rounds.inc(K)
            self.on_state_summary(engine.obs_state_summary())

    def on_round(self, *, cluster: int, a: int, dur: float,
                 consumed: float, loss: float, engine=None) -> None:
        """Event-loop flavor of `on_segment`: one round at a time."""
        self.m_rounds.inc(1)
        self.m_cluster_rounds.inc(1, cluster=str(int(cluster)))
        self.m_actions.inc(1, a=str(int(a)))
        self.m_energy.inc(float(consumed))
        self.m_sim.inc(float(dur))
        self.m_round_dur.observe(float(dur))
        self.m_loss.set(float(loss))
        if engine is not None:
            fm = getattr(engine, "faults", None)
            if fm is not None and fm.active:
                self.m_fault_rounds.inc(1)

    def on_state_summary(self, summary: dict) -> None:
        self.m_queue.set(summary["queue_deficit"])
        for stat in ("min", "mean", "max"):
            self.m_rep.set(summary[f"reputation_{stat}"], stat=stat)
        self.m_beta.set(summary["twin_beta_sum"])

    def on_eval(self, loss: float, acc=None) -> None:
        self.m_evals.inc(1)
        self.m_eval_loss.set(float(loss))
        if acc is not None:
            self.m_eval_acc.set(float(acc))

    def on_checkpoint(self, seconds: float, nbytes: int = 0) -> None:
        self.m_ckpts.inc(1)
        self.m_ckpt_s.observe(float(seconds))
        self.m_ckpt_last.set(float(seconds))
        if nbytes:
            self.m_ckpt_bytes.set(float(nbytes))

    def record_compile(self, fn_name: str, seconds: float,
                       hlo_text: Optional[str] = None) -> None:
        """One-time compile event: counters + HLO collective stats + an
        ``event/1`` record in metrics.jsonl."""
        self.m_compiles.inc(1, fn=fn_name)
        self.m_compile_s.inc(float(seconds), fn=fn_name)
        event = {"schema": EVENT_SCHEMA, "event": "compile",
                 "ts": time.time(), "fn": fn_name,
                 "seconds": float(seconds)}
        if hlo_text is not None:
            from repro.launch.hlo_stats import analyze_module
            try:
                st = analyze_module(hlo_text)
            except Exception:
                st = None
            if st is not None:
                self.m_hlo_coll.set(float(st.n_collective_ops), fn=fn_name)
                self.m_hlo_flops.set(float(st.flops), fn=fn_name)
                event["collective_ops"] = float(st.n_collective_ops)
                event["collectives"] = {k: float(v) for k, v
                                        in st.collectives.items()}
                event["flops"] = float(st.flops)
        if self.sink is not None:
            self.sink.append(event)
