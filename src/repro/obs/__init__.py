"""Fleet telemetry: metrics registry, timing spans, engine instrumentation.

See API.md "Observability".  `MetricsRegistry` (zero-dep counters /
gauges / histograms -> dict snapshot / Prometheus text), `SpanRecorder`
(nesting ``span("segment"|"round"|"checkpoint"|"eval"|"compile"|
"host_sync")`` timing trees with `block_until_ready` fencing), and
`EngineObs` (the bundle `DeviceScaleEngine.set_obs` / the serve stack
publish through, emitting schema-versioned records into a run dir's
``metrics.jsonl``).
"""
from .metrics import (DEFAULT_BUCKETS, METRICS_SCHEMA, Metric,
                      MetricsRegistry, load_metrics_file,
                      merge_snapshot_records, snapshot_record)
from .spans import SPAN_SCHEMA, Span, SpanRecorder, fence
from .instrument import EVENT_SCHEMA, EngineObs

__all__ = [
    "DEFAULT_BUCKETS", "METRICS_SCHEMA", "Metric", "MetricsRegistry",
    "load_metrics_file", "merge_snapshot_records", "snapshot_record",
    "SPAN_SCHEMA", "Span", "SpanRecorder", "fence",
    "EVENT_SCHEMA", "EngineObs",
]
