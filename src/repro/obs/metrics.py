"""A zero-dependency metrics registry: counters, gauges, histograms.

The fleet telemetry substrate (API.md "Observability").  Engines, the
serve supervisor, the chaos harness, and `FaultModel` bookkeeping all
publish into one `MetricsRegistry`; the registry snapshots to a plain
dict (JSON-serializable, schema-versioned — the ``metrics.jsonl`` record
form) and renders the Prometheus text exposition format, so the same
numbers feed the live terminal dashboard (``python -m repro.serve status
--watch``) and an external scraper polling ``python -m repro.serve
metrics``.

Design constraints, in order:

* **Never in the hot path's way.**  Publishing is host-side Python over
  scalars already synced (the engines batch metric updates per segment,
  mirroring their deferred-host-sync trace design) — nothing here touches
  jit, and instrumented runs compile the *identical* device program
  (tests/test_obs.py pins trace bit-parity with telemetry on vs off).
* **Bounded cardinality.**  Each family caps its label sets
  (``max_series``, default 64); past the cap, new label sets collapse
  into one reserved ``{"overflow": "true"}`` series and the registry's
  ``metrics_dropped_series_total`` self-counter ticks — a per-cluster
  label on a 4096-cluster fleet degrades gracefully instead of eating
  the process.
* **Round-trippable.**  ``snapshot()`` -> ``MetricsRegistry.
  from_snapshot`` is lossless, which is what lets a *separate* CLI
  process re-expose a run dir's last snapshot to Prometheus without
  talking to the live service.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

METRICS_SCHEMA = "metrics/1"            # snapshot record schema version

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_OVERFLOW = (("overflow", "true"),)

DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in key)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


class _Hist:
    """State of one histogram series: bucket counts + sum + count."""

    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)     # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0


class Metric:
    """One metric family: a name + kind + help + labeled series.

    ``inc``/``set``/``observe`` take the label values as keyword
    arguments (``m.inc(2, cluster="3")``); unlabeled use is the empty
    label set.  Counters only go up (negative increments raise), gauges
    set, histograms observe into fixed buckets.
    """

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help: str = "", buckets: Optional[Sequence[float]] = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets: Tuple[float, ...] = ()
        if kind == "histogram":
            bk = tuple(float(b) for b in (buckets or DEFAULT_BUCKETS))
            if list(bk) != sorted(bk):
                raise ValueError(f"histogram {name}: buckets must ascend")
            self.buckets = bk
        self._series: Dict[Tuple[Tuple[str, str], ...], object] = {}

    # ------------------------------------------------------------------ #
    def _slot(self, labels: Dict[str, str]):
        key = _label_key(labels)
        if key not in self._series:
            for k, _ in key:
                if not _LABEL_RE.match(k):
                    raise ValueError(f"invalid label name {k!r}")
            if len(self._series) >= self.registry.max_series \
                    and key != _OVERFLOW:
                # cardinality guard: collapse into the overflow series
                self.registry._dropped(self.name)
                key = _OVERFLOW
                if key in self._series:
                    return key
            self._series[key] = (_Hist(len(self.buckets))
                                 if self.kind == "histogram" else 0.0)
        return key

    def inc(self, value: float = 1.0, **labels) -> None:
        assert self.kind == "counter", f"{self.name} is a {self.kind}"
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        key = self._slot(labels)
        self._series[key] += value

    def set(self, value: float, **labels) -> None:
        assert self.kind == "gauge", f"{self.name} is a {self.kind}"
        key = self._slot(labels)
        self._series[key] = float(value)

    def observe(self, value: float, **labels) -> None:
        assert self.kind == "histogram", f"{self.name} is a {self.kind}"
        key = self._slot(labels)
        h = self._series[key]
        i = 0
        for i, edge in enumerate(self.buckets):
            if value <= edge:
                break
        else:
            i = len(self.buckets)
        h.counts[i] += 1
        h.sum += float(value)
        h.count += 1

    def value(self, **labels) -> float:
        """Current value of one series (counter/gauge); 0.0 if unseen."""
        v = self._series.get(_label_key(labels), 0.0)
        return v.count if isinstance(v, _Hist) else float(v)

    def total(self) -> float:
        """Sum over all series (histograms: total observation count)."""
        return sum(v.count if isinstance(v, _Hist) else float(v)
                   for v in self._series.values())

    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        series: List[dict] = []
        for key, v in sorted(self._series.items()):
            d: dict = {"labels": dict(key)}
            if isinstance(v, _Hist):
                d.update(counts=list(v.counts), sum=v.sum, count=v.count)
            else:
                d["value"] = float(v)
            series.append(d)
        out = {"kind": self.kind, "help": self.help, "series": series}
        if self.kind == "histogram":
            out["buckets"] = list(self.buckets)
        return out

    def load_dict(self, d: dict) -> None:
        for s in d.get("series", []):
            key = _label_key(s.get("labels", {}))
            if self.kind == "histogram":
                h = _Hist(len(self.buckets))
                h.counts = list(s.get("counts", h.counts))
                h.sum = float(s.get("sum", 0.0))
                h.count = int(s.get("count", 0))
                self._series[key] = h
            else:
                self._series[key] = float(s.get("value", 0.0))


class MetricsRegistry:
    """A named collection of metric families (see module docstring)."""

    def __init__(self, max_series: int = 64):
        self.max_series = int(max_series)
        self._metrics: Dict[str, Metric] = {}
        self._drop_counts: Dict[str, int] = {}

    # declaration ------------------------------------------------------ #
    def _declare(self, name, kind, help, buckets=None) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind:
                raise ValueError(
                    f"metric {name} already registered as {m.kind}")
            return m
        m = Metric(self, name, kind, help, buckets)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Metric:
        return self._declare(name, "counter", help)

    def gauge(self, name: str, help: str = "") -> Metric:
        return self._declare(name, "gauge", help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Metric:
        return self._declare(name, "histogram", help, buckets)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def _dropped(self, name: str) -> None:
        self._drop_counts[name] = self._drop_counts.get(name, 0) + 1
        c = self._declare("metrics_dropped_series_total", "counter",
                          "label sets collapsed by the cardinality guard")
        c._series[_label_key({"metric": name})] = \
            c._series.get(_label_key({"metric": name}), 0.0) + 1.0

    def __iter__(self) -> Iterable[Metric]:
        return iter(self._metrics.values())

    # snapshots -------------------------------------------------------- #
    def snapshot(self) -> dict:
        """Schema-versioned, JSON-round-trippable state of every family."""
        return {"schema": METRICS_SCHEMA,
                "families": {n: m.to_dict()
                             for n, m in sorted(self._metrics.items())}}

    @classmethod
    def from_snapshot(cls, snap: dict,
                      max_series: int = 4096) -> "MetricsRegistry":
        reg = cls(max_series=max_series)
        reg.load_snapshot(snap)
        return reg

    def load_snapshot(self, snap: dict) -> None:
        if snap.get("schema", METRICS_SCHEMA) != METRICS_SCHEMA:
            raise ValueError(
                f"unsupported metrics schema {snap.get('schema')!r}")
        for name, fam in snap.get("families", {}).items():
            m = self._declare(name, fam.get("kind", "gauge"),
                              fam.get("help", ""), fam.get("buckets"))
            m.load_dict(fam)

    def totals(self) -> Dict[str, float]:
        """Flat {name: total} view — the dashboard/status summary form."""
        return {n: m.total() for n, m in sorted(self._metrics.items())}

    # Prometheus text exposition --------------------------------------- #
    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, v in sorted(m._series.items()):
                if isinstance(v, _Hist):
                    cum = 0
                    for i, edge in enumerate(m.buckets):
                        cum += v.counts[i]
                        k2 = key + (("le", _fmt_value(edge)),)
                        lines.append(f"{name}_bucket"
                                     f"{_fmt_labels(tuple(sorted(k2)))}"
                                     f" {cum}")
                    cum += v.counts[-1]
                    k2 = key + (("le", "+Inf"),)
                    lines.append(f"{name}_bucket"
                                 f"{_fmt_labels(tuple(sorted(k2)))} {cum}")
                    lines.append(f"{name}_sum{_fmt_labels(key)} "
                                 f"{_fmt_value(v.sum)}")
                    lines.append(f"{name}_count{_fmt_labels(key)} "
                                 f"{v.count}")
                else:
                    lines.append(
                        f"{name}{_fmt_labels(key)} {_fmt_value(v)}")
        return "\n".join(lines) + ("\n" if lines else "")


# --------------------------------------------------------------------- #
# snapshot streams (the metrics.jsonl record form)
# --------------------------------------------------------------------- #
def snapshot_record(registry: MetricsRegistry, *, source: str,
                    ts: float) -> dict:
    """One ``metrics.jsonl`` snapshot record for this registry."""
    rec = registry.snapshot()
    rec.update(source=str(source), ts=float(ts))
    return rec


def merge_snapshot_records(records: Iterable[dict]) -> Optional[dict]:
    """Fold a stream of snapshot records into one merged snapshot.

    Multiple *sources* write snapshots into the same ``metrics.jsonl``
    (the service between segments, the chaos supervisor around kills);
    each source's **latest** record wins for that source, and families
    merge across sources (sources use disjoint name prefixes —
    ``fl_``/``service_`` vs ``chaos_`` — so a later source never
    clobbers an earlier one's counters).  Returns None when no snapshot
    records are present.
    """
    latest: Dict[str, dict] = {}
    for rec in records:
        if rec.get("schema") == METRICS_SCHEMA:
            latest[str(rec.get("source", ""))] = rec
    if not latest:
        return None
    families: Dict[str, dict] = {}
    for _, rec in sorted(latest.items(),
                         key=lambda kv: kv[1].get("ts", 0.0)):
        families.update(rec.get("families", {}))
    ts = max(r.get("ts", 0.0) for r in latest.values())
    return {"schema": METRICS_SCHEMA, "source": "merged", "ts": ts,
            "families": families}


def load_metrics_file(path: str, *, tail: int = 512
                      ) -> Optional[MetricsRegistry]:
    """Registry rebuilt from the last snapshot(s) of a metrics.jsonl.

    Reads only the file's tail (`repro.api.records.tail_jsonl`), so a
    scrape of a long-serving run dir stays O(tail)."""
    from repro.api.records import tail_jsonl
    merged = merge_snapshot_records(tail_jsonl(path, n=tail))
    if merged is None:
        return None
    return MetricsRegistry.from_snapshot(merged)


def dumps(snapshot: dict) -> str:
    return json.dumps(snapshot, separators=(",", ":"))
