"""Structured timing spans with device fencing.

``SpanRecorder.span(name)`` is a context manager producing a *tree* of
timed spans — ``segment`` wraps ``round``/``checkpoint`` wraps
``host_sync``/``eval`` — so a serve segment's wall-clock decomposes into
host-dispatch vs device-compute vs checkpoint-I/O instead of one opaque
number.  The honesty comes from **fencing**: passing ``fence=pytree``
makes the span call ``jax.block_until_ready`` on that tree before
stamping its end time, so a span that dispatched async device work is
charged for the compute it launched, not just the Python time it spent
enqueueing it.  A ``Span.mark("dispatch")`` inside the body records the
dispatch→fence split as an attribute.

Completed **root** spans are emitted to an optional sink (the run dir's
``metrics.jsonl``, via the same `JsonlSink` machinery as ``trace.jsonl``)
as schema-versioned records::

    {"schema": "span/1", "ts": <unix>, "name": "segment", "dur_s": ...,
     "attrs": {...}, "children": [{"name": "round", ...}, ...]}

Child spans nest inside their parent's ``children`` and are not emitted
separately.  The recorder is not thread-safe; each engine/serve process
owns its own.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

SPAN_SCHEMA = "span/1"


def fence(tree: Any) -> Any:
    """`jax.block_until_ready`, tolerating non-array pytrees and
    environments where jax is absent (the registry is zero-dep; spans
    only need jax when actually fencing device values)."""
    try:
        import jax
        return jax.block_until_ready(tree)
    except Exception:
        return tree


class Span:
    """One timed node in the tree.  ``dur_s`` is set on exit."""

    __slots__ = ("name", "ts", "dur_s", "attrs", "children", "_t0")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.ts = time.time()
        self._t0 = time.perf_counter()
        self.dur_s: float = 0.0
        self.attrs = dict(attrs)
        self.children: List["Span"] = []

    def mark(self, label: str) -> float:
        """Record elapsed-so-far as attr ``<label>_s`` (e.g. the
        dispatch→fence boundary inside a fenced round span)."""
        dt = time.perf_counter() - self._t0
        self.attrs[f"{label}_s"] = dt
        return dt

    def child_dur(self, name: str) -> float:
        return sum(c.dur_s for c in self.children if c.name == name)

    def to_dict(self) -> dict:
        d: dict = {"name": self.name, "ts": self.ts, "dur_s": self.dur_s}
        if self.attrs:
            d["attrs"] = self.attrs
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


class SpanRecorder:
    """Builds span trees; emits completed roots to ``sink`` and retains
    the last ``max_retained`` roots in ``.finished`` for in-process
    consumers (benchmarks, tests, the dashboard's same-process path)."""

    def __init__(self, sink=None, retain: bool = True,
                 max_retained: int = 256):
        self.sink = sink
        self.retain = bool(retain)
        self.finished: deque = deque(maxlen=int(max_retained))
        self._stack: List[Span] = []

    @property
    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    @contextmanager
    def span(self, name: str, fence_on: Any = None, **attrs):
        sp = Span(name, attrs)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            if fence_on is not None:
                fence(fence_on)
            sp.dur_s = time.perf_counter() - sp._t0
            self._stack.pop()
            if self._stack:
                self._stack[-1].children.append(sp)
            else:
                if self.retain:
                    self.finished.append(sp)
                if self.sink is not None:
                    self.sink.append({"schema": SPAN_SCHEMA, **sp.to_dict()})

    def last(self, name: Optional[str] = None) -> Optional[Span]:
        """Most recent finished root span (optionally by name)."""
        for sp in reversed(self.finished):
            if name is None or sp.name == name:
                return sp
        return None
