"""Synthetic data sources (offline container — no dataset downloads).

* ``make_classification`` — MNIST-shaped 10-class prototype task (784-dim
  inputs, additive noise, class-dependent structure).  Used by the paper-repro
  benchmarks (Figs 3, 6, 7, 8) in place of MNIST.
* ``token_stream`` — Zipf-distributed LM token streams for the assigned
  architectures' smoke tests and example drivers.
* ``make_iot_telemetry`` — non-IID industrial-IoT sensor telemetry for the
  federated anomaly-detection task: each *device type* (equipment family)
  emits readings on its own low-dimensional operating manifold, and a small
  fraction of samples carry injected faults (off-manifold spikes).  The
  ``device_type`` column is the non-IID partition key — feed it to
  ``dirichlet_partition`` so each client sees mostly one equipment family.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticClassification(NamedTuple):
    x: jnp.ndarray       # (N, dim)
    y: jnp.ndarray       # (N,) int32
    prototypes: jnp.ndarray


def make_classification(key, n: int = 8192, dim: int = 784,
                        n_classes: int = 10, noise: float = 0.8
                        ) -> SyntheticClassification:
    kp, ky, kx = jax.random.split(key, 3)
    protos = jax.random.normal(kp, (n_classes, dim))
    y = jax.random.randint(ky, (n,), 0, n_classes)
    x = protos[y] + noise * jax.random.normal(kx, (n, dim))
    return SyntheticClassification(x=x, y=y, prototypes=protos)


class SyntheticTelemetry(NamedTuple):
    x: jnp.ndarray            # (N, dim) sensor feature vectors
    y: jnp.ndarray            # (N,) int32, 1 = anomalous sample
    device_type: jnp.ndarray  # (N,) int32 equipment family (partition key)


def make_iot_telemetry(key, n: int = 2048, dim: int = 32, n_types: int = 8,
                       latent: int = 4, anomaly_frac: float = 0.05,
                       noise: float = 0.05, spike: float = 4.0,
                       spike_frac: float = 0.25) -> SyntheticTelemetry:
    """Synthetic IIoT telemetry with type-structured normals and injected
    faults.

    Each device type t has an operating point ``mean_t`` and a ``latent``-dim
    loading matrix ``A_t``; a normal reading is ``mean_t + z @ A_t + noise``
    — i.e. normal telemetry of a family lies near a ``latent``-dimensional
    affine manifold an autoencoder can learn.  A Bernoulli(anomaly_frac)
    subset of samples additionally gets heavy off-manifold spikes on a
    random ``spike_frac`` of coordinates (stuck/drifting sensors), labelled
    ``y = 1``.  Anomalies are left *in* the training stream — the realistic
    contaminated-data regime — and the labels are for evaluation only.
    """
    kt, km, ka, kz, kn, kf, kc, ks = jax.random.split(key, 8)
    dtype_ids = jax.random.randint(kt, (n,), 0, n_types)
    means = 2.0 * jax.random.normal(km, (n_types, dim))
    loadings = jax.random.normal(ka, (n_types, latent, dim)) / jnp.sqrt(
        jnp.float32(latent))
    z = jax.random.normal(kz, (n, latent))
    x = means[dtype_ids] + jnp.einsum("nl,nld->nd", z, loadings[dtype_ids])
    x = x + noise * jax.random.normal(kn, (n, dim))
    is_anom = jax.random.bernoulli(kf, anomaly_frac, (n,))
    coord = jax.random.bernoulli(kc, spike_frac, (n, dim))
    x = x + (is_anom[:, None] & coord) * spike * jax.random.normal(
        ks, (n, dim))
    return SyntheticTelemetry(x=x, y=is_anom.astype(jnp.int32),
                              device_type=dtype_ids.astype(jnp.int32))


def token_stream(key, n_tokens: int, vocab: int, zipf_a: float = 1.2
                 ) -> jnp.ndarray:
    """Zipf-distributed token ids — realistic rank-frequency for LM smokes."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -zipf_a
    p /= p.sum()
    return jnp.asarray(
        jax.random.choice(key, vocab, (n_tokens,), p=jnp.asarray(p)),
        jnp.int32)


def lm_batches(key, vocab: int, batch: int, seq: int, n_batches: int,
               codebooks: int = 1) -> Iterator[dict]:
    """Next-token-prediction batches from a synthetic stream."""
    for i in range(n_batches):
        key, kb = jax.random.split(key)
        shape = (batch, seq + 1) if codebooks == 1 else (batch, codebooks, seq + 1)
        toks = token_stream(kb, int(np.prod(shape)), vocab).reshape(shape)
        yield {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
