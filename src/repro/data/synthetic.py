"""Synthetic data sources (offline container — no dataset downloads).

* ``make_classification`` — MNIST-shaped 10-class prototype task (784-dim
  inputs, additive noise, class-dependent structure).  Used by the paper-repro
  benchmarks (Figs 3, 6, 7, 8) in place of MNIST.
* ``token_stream`` — Zipf-distributed LM token streams for the assigned
  architectures' smoke tests and example drivers.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticClassification(NamedTuple):
    x: jnp.ndarray       # (N, dim)
    y: jnp.ndarray       # (N,) int32
    prototypes: jnp.ndarray


def make_classification(key, n: int = 8192, dim: int = 784,
                        n_classes: int = 10, noise: float = 0.8
                        ) -> SyntheticClassification:
    kp, ky, kx = jax.random.split(key, 3)
    protos = jax.random.normal(kp, (n_classes, dim))
    y = jax.random.randint(ky, (n,), 0, n_classes)
    x = protos[y] + noise * jax.random.normal(kx, (n, dim))
    return SyntheticClassification(x=x, y=y, prototypes=protos)


def token_stream(key, n_tokens: int, vocab: int, zipf_a: float = 1.2
                 ) -> jnp.ndarray:
    """Zipf-distributed token ids — realistic rank-frequency for LM smokes."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -zipf_a
    p /= p.sum()
    return jnp.asarray(
        jax.random.choice(key, vocab, (n_tokens,), p=jnp.asarray(p)),
        jnp.int32)


def lm_batches(key, vocab: int, batch: int, seq: int, n_batches: int,
               codebooks: int = 1) -> Iterator[dict]:
    """Next-token-prediction batches from a synthetic stream."""
    for i in range(n_batches):
        key, kb = jax.random.split(key)
        shape = (batch, seq + 1) if codebooks == 1 else (batch, codebooks, seq + 1)
        toks = token_stream(kb, int(np.prod(shape)), vocab).reshape(shape)
        yield {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
