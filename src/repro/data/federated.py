"""Federated (non-IID) data partitioning.

``dirichlet_partition`` assigns class-skewed shards to clients — the standard
non-IID benchmark setup matching the paper's heterogeneous-device scenario;
``federated_batches`` materializes per-client fixed-size batches (struct-of-
arrays with a leading client dim) for the vmap-ed mode-A train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dirichlet_partition(key, labels, n_clients: int, alpha: float = 0.5,
                        n_classes: int | None = None):
    """-> list of index arrays, one per client (non-IID by class skew)."""
    labels = np.asarray(labels)
    n_classes = n_classes or int(labels.max()) + 1
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    out = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(idx, cuts)):
            out[cl].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in out]


def federated_batches(key, x, y, parts, batch: int):
    """Sample one (n_clients, batch, ...) federated batch."""
    n = len(parts)
    keys = jax.random.split(key, n)
    xs, ys = [], []
    for k, ix in zip(keys, parts):
        sel = jax.random.choice(k, jnp.asarray(ix), (batch,),
                                replace=len(ix) < batch)
        xs.append(x[sel])
        ys.append(y[sel])
    return jnp.stack(xs), jnp.stack(ys)
