"""Federated (non-IID) data partitioning.

``dirichlet_partition`` assigns class-skewed shards to clients — the standard
non-IID benchmark setup matching the paper's heterogeneous-device scenario;
``federated_batches`` materializes per-client fixed-size batches (struct-of-
arrays with a leading client dim) for the vmap-ed mode-A train step.

``padded_partition`` + ``sample_member_batch`` are the jit-safe pipeline the
fused `FleetState` cluster round gathers from: the ragged per-client index
lists become one (n, W) matrix at init, and batch selection is a fixed-shape
vmap of per-member randint draws — no Python list assembly, no host syncs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dirichlet_partition(key, labels, n_clients: int, alpha: float = 0.5,
                        n_classes: int | None = None):
    """-> list of index arrays, one per client (non-IID by class skew)."""
    labels = np.asarray(labels)
    n_classes = n_classes or int(labels.max()) + 1
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    out = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(idx, cuts)):
            out[cl].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in out]


def uniform_cycle_partition(n_samples: int, n_devices: int):
    """Round-robin shards: device i owns rows {i, i+n, i+2n, ...}.

    The O(1)-per-device partition the capacity benchmarks use at
    n_devices >= 10^4, where a dirichlet draw (and its Python list
    assembly) dominates setup time.  Every shard is non-empty as long as
    ``n_samples >= n_devices`` — smaller fleets wrap around so row
    ``i % n_samples`` seeds device i.
    """
    if n_samples >= n_devices:
        return [np.arange(i, n_samples, n_devices, dtype=np.int64)
                for i in range(n_devices)]
    return [np.asarray([i % n_samples], dtype=np.int64)
            for i in range(n_devices)]


def padded_partition(parts):
    """Pack ragged per-client index lists into one fixed-shape matrix.

    -> (part_idx (n, W) int32, part_len (n,) int32) with W = max shard size;
    rows are zero-padded past their length.  Precomputed once at engine init
    so the jitted round can gather batches without materializing Python
    lists.  An empty shard is rejected here, loudly: inside the fixed-shape
    round it would silently train that client on dataset row 0 forever
    (re-draw the partition, e.g. with a larger dirichlet alpha).
    """
    n = len(parts)
    empty = [i for i, p in enumerate(parts) if len(p) == 0]
    if empty:
        raise ValueError(f"clients {empty} have empty data shards; every "
                         "client needs >= 1 sample (re-draw the partition)")
    w = max((len(p) for p in parts), default=1)
    idx = np.zeros((n, max(w, 1)), dtype=np.int32)
    length = np.zeros((n,), dtype=np.int32)
    for i, p in enumerate(parts):
        p = np.asarray(p, dtype=np.int32)
        idx[i, :len(p)] = p
        length[i] = len(p)
    return jnp.asarray(idx), jnp.asarray(length)


def sample_member_batch(key, part_idx, part_len, members, batch: int):
    """Fixed-shape federated batch selection for one cluster round.

    members: (M,) device ids, possibly holding the out-of-range padding
    sentinel n (gathers fill, so padded rows draw from client 0's shard and
    are masked downstream).  Each member samples ``batch`` indices with
    replacement from its own shard under a per-member key
    ``fold_in(key, id)`` — the stream depends only on (key, id, shard), so
    padded and exact-shape execution draw identical batches.

    -> (M, batch) int32 row indices into the dataset.
    """
    def one(m):
        k = jax.random.fold_in(key, m)
        n_i = part_len.at[m].get(mode="fill", fill_value=1)
        sel = jax.random.randint(k, (batch,), 0, jnp.maximum(n_i, 1))
        row = part_idx.at[m].get(mode="fill", fill_value=0)
        return row[sel]

    return jax.vmap(one)(members)


def federated_batches(key, x, y, parts, batch: int):
    """Sample one (n_clients, batch, ...) federated batch."""
    n = len(parts)
    keys = jax.random.split(key, n)
    xs, ys = [], []
    for k, ix in zip(keys, parts):
        sel = jax.random.choice(k, jnp.asarray(ix), (batch,),
                                replace=len(ix) < batch)
        xs.append(x[sel])
        ys.append(y[sel])
    return jnp.stack(xs), jnp.stack(ys)
