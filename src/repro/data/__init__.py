from .synthetic import (SyntheticClassification, make_classification,
                        token_stream, lm_batches)
from .federated import (dirichlet_partition, federated_batches,
                        padded_partition, sample_member_batch)

__all__ = ["SyntheticClassification", "make_classification", "token_stream",
           "lm_batches", "dirichlet_partition", "federated_batches",
           "padded_partition", "sample_member_batch"]
