from .synthetic import (SyntheticClassification, SyntheticTelemetry,
                        make_classification, make_iot_telemetry,
                        token_stream, lm_batches)
from .federated import (dirichlet_partition, federated_batches,
                        padded_partition, sample_member_batch)

__all__ = ["SyntheticClassification", "SyntheticTelemetry",
           "make_classification", "make_iot_telemetry", "token_stream",
           "lm_batches", "dirichlet_partition", "federated_batches",
           "padded_partition", "sample_member_batch"]
