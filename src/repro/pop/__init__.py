"""Population engine: B federations as one vmapped device program.

    from repro.pop import PopulationSpec, PopulationEngine, member_seed

    pspec = PopulationSpec(base=FederationSpec(...),
                           grid={"lr": [0.05, 0.1]}, replicates=4)
    traces = PopulationEngine.from_population(pspec).run_scanned(K)

Each returned trace is bit-identical to the standalone
``Federation.from_spec(member_spec).run_scanned(K)`` run of the matching
expanded spec.  `python -m repro.serve pool` serves a population across
checkpointed segments into per-member run dirs.
"""
from .engine import PopulationEngine, PopulationMember
from .spec import PopulationSpec, member_seed

__all__ = ["PopulationEngine", "PopulationMember", "PopulationSpec",
           "member_seed"]
