"""`PopulationEngine`: B independent federations as one vmapped scan.

PR 2/4 made an entire federation a pure function of its SoA `FleetState`
driven by `lax.scan`; a population of B federations is therefore just one
more batch axis.  This engine builds B real `DeviceScaleEngine`s from
member specs (so data, partitions, cluster assignments, and malicious
masks come from the exact standalone construction code), stacks their
states and padded tables along a leading population axis, and `jax.vmap`s
the *unmodified* fused round + in-jit controller + Eqn-12 queue over it —
`run_scanned(K)` executes all B federations in a single device program and
unstacks per-member `FLTrace`s bit-identical to standalone
``Federation.from_spec(spec).run_scanned(K)`` runs.

Member heterogeneity splits into three classes:

build-time   fields only read at construction (seed, data params,
             malicious_frac, dt_max_dev, channel p_good, fault subsets):
             realized per member by the standalone constructors, stacked.
lifted       scalar knobs read inside the round (lr, iota, pkt_fail, DP
             sigma, alpha0/alpha_growth, fault intensities, Lyapunov
             budget/penalty, the trust-vs-fedavg flag): lifted into traced
             per-member arrays and rebound through a `_MemberView` —
             a duck-typed `self` whose spec fields hold tracers.
static       everything that changes the compiled program (shapes,
             component kinds, fault gates `may_*`, corrupt_mode, DP
             on/off, calibrate_dt): must be uniform; checked at build.

Ragged per-member widths (padded membership M, partition width W) pad to
the population-wide maximum — bitwise-neutral, since fill-gathers never
read padded columns and masked reductions only append zeros.

The population axis shards over a 1-D mesh (`ShardingSpec`, axis "pop"):
members are independent, so the program partitions with zero collectives —
one host serves ``device_count`` times the population at the same
wall-clock.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.api.components import WeightedAggregator
from repro.api.engine import DeviceScaleEngine
from repro.api.records import FLTrace, RoundRecord
from repro.api.spec import FederationSpec
from repro.control import policy as ctl_policy
from repro.control import queue as ctl_queue
from repro.core.envs import OBS_DIM
from repro.faults.model import FaultModel

from .spec import POP_AXIS, PopulationSpec

__all__ = ["PopulationEngine", "PopulationMember"]


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(f"population: {msg}")


def _uniform(specs, label: str, get):
    vals = [get(s) for s in specs]
    _require(all(v == vals[0] for v in vals),
             f"{label} must be uniform across the population (it is "
             f"compiled static); got {vals}")
    return vals[0]


class _MemberView(DeviceScaleEngine):
    """A duck-typed `DeviceScaleEngine` carrying one member's vmap-sliced
    leaves and lifted spec scalars.  Only the attributes the fused round /
    controller features read are set; the round methods themselves are
    inherited unmodified — the population runs the exact standalone
    device math."""

    def __init__(self, **attrs):          # noqa: D401 — attribute bag
        for k, v in attrs.items():
            setattr(self, k, v)


class _FaultView(FaultModel):
    """`FaultModel` over lifted per-member fault scalars.  The static
    ``may_*`` gates come from the (uniform) base spec so the compiled
    program is member-independent; the probabilities/scales the jnp
    methods read are tracers."""

    def __init__(self, base: FaultModel, p: Dict[str, Any]):
        self._base = base.spec
        self.n = base.n
        self.corrupt_dev = p["corrupt_dev"]
        self.poison_dev = p["poison_dev"]
        self._seed = p.get("seed", base._seed)
        self.spec = dataclasses.replace(
            base.spec, dropout=p["dropout"],
            straggler_frac=p["straggler_frac"],
            straggler_factor=p["straggler_factor"],
            twin_spike_prob=p["twin_spike_prob"],
            twin_spike_scale=p["twin_spike_scale"],
            corrupt_scale=p["corrupt_scale"],
            poison_scale=p["poison_scale"])

    active = property(lambda self: self._base.active)
    may_drop = property(lambda self: self._base.may_drop)
    may_straggle = property(lambda self: self._base.may_straggle)
    may_spike = property(lambda self: self._base.may_spike)
    may_corrupt = property(lambda self: self._base.may_corrupt)
    may_poison = property(lambda self: self._base.may_poison)


class _LiftedWeightedAggregator(WeightedAggregator):
    """Trust/fedavg selected by a traced per-member flag: both weight
    vectors are computed and `jnp.where`-selected, so the selected lane is
    bitwise-identical to the corresponding standalone branch."""

    def __init__(self, use_kernel: bool, uniform_flag):
        super().__init__(uniform=False, use_kernel=use_kernel)
        self._flag = uniform_flag         # () bool tracer: True = fedavg

    def _effective_weights(self, weights, mask):
        m = mask.astype(weights.dtype)
        uni = m / jnp.maximum(jnp.sum(m), 1.0)
        return jnp.where(self._flag, uni, weights)


# lifted FederationSpec scalars: (mp key, getter)
_LIFTED_SPEC = (
    ("lr", lambda s: s.lr),
    ("iota", lambda s: s.iota),
    ("pkt_fail", lambda s: s.channel.pkt_fail),
    ("noise", lambda s: s.privacy.noise),
    ("alpha0", lambda s: s.clustering.alpha0),
    ("alpha_growth", lambda s: s.clustering.alpha_growth),
)
_LIFTED_FAULT = ("dropout", "straggler_frac", "straggler_factor",
                 "twin_spike_prob", "twin_spike_scale", "corrupt_scale",
                 "poison_scale")


class PopulationEngine:
    """B federations, one device program (see module docstring)."""

    def __init__(self, specs: Sequence[FederationSpec], *,
                 sharding=None, pop_axis: str = POP_AXIS,
                 federations: Optional[Sequence[Any]] = None):
        from repro.api.federation import Federation
        self.specs = [s for s in specs]
        self.B = len(self.specs)
        _require(self.B >= 1, "need at least one member spec")
        if federations is None:
            federations = [Federation.from_spec(s, controller=c)
                           for s, c in zip(self.specs,
                                           self._build_controllers())]
        self.federations = list(federations)
        engines = [f.engine for f in self.federations]
        self._check_static(engines)
        e0 = engines[0]
        self._proto = e0
        self.task = e0.task
        self.n_devices = int(e0.spec.fleet.n_devices)
        self.n_clusters = int(e0.spec.clustering.n_clusters)

        # --- stack member state + tables (padded to population-wide M/W)
        stack = lambda xs: jnp.stack(list(xs))                 # noqa: E731
        self.state = jax.tree.map(lambda *ls: jnp.stack(ls),
                                  *[e.state for e in engines])
        self._scan_times = stack(e._scan_times for e in engines)
        M = max(e._member_table.shape[1] for e in engines)
        W = max(e._part_idx.shape[1] for e in engines)
        n = self.n_devices

        def pad_tbl(e):
            pad = M - e._member_table.shape[1]
            tbl = jnp.pad(e._member_table, ((0, 0), (0, pad)),
                          constant_values=n)
            msk = jnp.pad(e._member_mask, ((0, 0), (0, pad)),
                          constant_values=False)
            return tbl, msk

        tbls, msks = zip(*(pad_tbl(e) for e in engines))
        mp: Dict[str, Any] = {
            "x": stack(e._x for e in engines),
            "y": stack(e._y for e in engines),
            "part_idx": stack(
                jnp.pad(e._part_idx,
                        ((0, 0), (0, W - e._part_idx.shape[1])))
                for e in engines),
            "part_len": stack(e._part_len for e in engines),
            "member_table": stack(tbls),
            "member_mask": stack(msks),
            "malicious": stack(e._malicious_dev for e in engines),
            "misbehaving": stack(e._misbehaving_dev for e in engines),
            "trans": stack(e._trans for e in engines),
            "per_slot": jnp.asarray(
                [ctl_queue.per_slot_of(f.controller)
                 for f in self.federations], jnp.float32),
        }
        for key, get in _LIFTED_SPEC:
            mp[key] = jnp.asarray([float(get(s)) for s in self.specs],
                                  jnp.float32)
        if e0.faults.active:
            flt = {k: jnp.asarray(
                [float(getattr(s.faults, k)) for s in self.specs],
                jnp.float32) for k in _LIFTED_FAULT}
            flt["corrupt_dev"] = stack(e.faults.corrupt_dev
                                       for e in engines)
            flt["poison_dev"] = stack(e.faults.poison_dev for e in engines)
            seeds = [int(s.faults.seed) for s in self.specs]
            if any(sd != seeds[0] for sd in seeds):
                # poison patterns derive from the seed with host-side
                # integer arithmetic — they cannot trace (checked below)
                flt["seed"] = jnp.asarray(seeds, jnp.int32)
            mp["flt"] = flt
        agg_kinds = {s.aggregator.kind for s in self.specs}
        self._lift_agg = agg_kinds == {"trust", "fedavg"}
        if self._lift_agg:
            mp["agg_uniform"] = jnp.asarray(
                [s.aggregator.kind == "fedavg" for s in self.specs], bool)
        self._pol_step, self._pol_needs_obs, pol_mp = self._build_policy()
        if pol_mp:
            mp["pol"] = pol_mp
        self._mp = mp

        # --- optional population-axis placement
        self.mesh: Optional[Mesh] = None
        self.pop_axis = pop_axis
        if sharding is not None and getattr(sharding, "is_sharded", False):
            _require(len(sharding.mesh) == 1,
                     "the population shards over a 1-D mesh (one pop axis)")
            shards = int(sharding.mesh[0])
            _require(self.B % shards == 0,
                     f"mesh has {shards} shards, which does not divide the "
                     f"population size {self.B}")
            if sharding.axes:
                self.pop_axis = sharding.axes[0]
            from repro.api.placement import _mesh_devices
            self.mesh = Mesh(_mesh_devices((shards,)), (self.pop_axis,))
            sh = NamedSharding(self.mesh, PartitionSpec(self.pop_axis))
            put = lambda t: jax.tree.map(                      # noqa: E731
                lambda l: jax.device_put(l, sh), t)
            self.state = put(self.state)
            self._scan_times = jax.device_put(self._scan_times, sh)
            self._mp = put(self._mp)

        self._rounds = [0] * self.B
        self._energy_used = [0.0] * self.B      # exact f64, per member
        self._sinks: List[Any] = [None] * self.B
        self._retain = [True] * self.B
        self._scan_cache: Dict[int, Any] = {}

    # ------------------------------------------------------------------ #
    @classmethod
    def from_population(cls, pspec: PopulationSpec) -> "PopulationEngine":
        return cls(pspec.expand(), sharding=pspec.sharding,
                   pop_axis=pspec.pop_axis())

    def _build_controllers(self):
        """Member controllers from the registries; identical DQN pretrains
        are built once and shared (the agent is immutable at deploy time —
        fixed/lyapunov controllers carry per-member queue state and are
        always built per member)."""
        from repro.api import registry
        cache: Dict[str, Any] = {}
        out = []
        for s in self.specs:
            factory = registry.CONTROLLERS.get(s.controller.kind)
            if s.controller.kind == "dqn":
                key = json.dumps(s.controller.params, sort_keys=True,
                                 default=repr)
                if key not in cache:
                    cache[key] = factory(s.controller.params)
                out.append(cache[key])
            else:
                out.append(factory(s.controller.params))
        return out

    # ------------------------------------------------------------------ #
    def _check_static(self, engines) -> None:
        specs = self.specs
        for e in engines:
            _require(type(e) is DeviceScaleEngine,
                     f"member engines must be unsharded device-scale "
                     f"engines; got {type(e).__name__}")
            _require(e._padded, "members need a mask-aware aggregator "
                     "(run_scanned's padded fused round)")
        _uniform(specs, "fleet.n_devices", lambda s: s.fleet.n_devices)
        _uniform(specs, "clustering.n_clusters",
                 lambda s: s.clustering.n_clusters)
        _uniform(specs, "local_batch", lambda s: s.local_batch)
        _uniform(specs, "task", lambda s: (s.task.kind,
                                           sorted(s.task.params.items())))
        _uniform(specs, "controller.kind", lambda s: s.controller.kind)
        _uniform(specs, "fleet.calibrate_dt",
                 lambda s: s.fleet.calibrate_dt)
        _uniform(specs, "privacy.clip", lambda s: s.privacy.clip)
        _uniform(specs, "aggregator.use_kernel",
                 lambda s: s.aggregator.use_kernel)
        agg_kinds = {s.aggregator.kind for s in specs}
        if len(agg_kinds) > 1:
            _require(agg_kinds == {"trust", "fedavg"},
                     f"mixed aggregator kinds {sorted(agg_kinds)} — only "
                     "the trust/fedavg pair lifts to a traced flag")
            _require(specs[0].privacy.clip <= 0.0,
                     "mixed trust/fedavg aggregators cannot combine with "
                     "DP (the DP weight path branches on the kind)")
        else:
            _uniform(specs, "aggregator.params",
                     lambda s: sorted(s.aggregator.params.items()))
        for gate in ("may_drop", "may_straggle", "may_spike",
                     "may_corrupt", "may_poison"):
            _uniform(specs, f"faults.{gate}",
                     lambda s, g=gate: getattr(s.faults, g))
        if specs[0].faults.may_corrupt:
            _uniform(specs, "faults.corrupt_mode",
                     lambda s: s.faults.corrupt_mode)
        if specs[0].faults.may_poison:
            _uniform(specs, "faults.seed (with poisoning on: the poison "
                     "patterns derive from it statically)",
                     lambda s: s.faults.seed)
        _require(len({e._n_actions for e in engines}) == 1,
                 "controller n_actions must be uniform")
        _require(len({e._fused_global for e in engines}) == 1,
                 "aggregator fused-global support must be uniform")

    # ------------------------------------------------------------------ #
    def _build_policy(self):
        """The population scan policy: per-member scalar knobs lifted into
        ``mp["pol"]``, identical math to `repro.control.policy`."""
        ctls = [f.controller for f in self.federations]
        kind = self.specs[0].controller.kind
        pols = [c.scan_policy() for c in ctls]
        if kind == "fixed":
            pol_mp = {"a": jnp.asarray([int(c.a) for c in ctls],
                                       jnp.int32)}

            def step(state, obs, p):
                return p["a"], state
            return step, False, pol_mp
        if kind == "lyapunov":
            pol_mp = {k: jnp.asarray([float(getattr(c, k)) for c in ctls],
                                     jnp.float32)
                      for k in ("kappa", "f_star", "v0", "v_growth")}
            n_actions = int(ctls[0].n_actions)

            def step(state, obs, p):
                s = ctl_policy.lyapunov_scores(
                    obs.queue, obs.round, obs.cluster_loss, obs.mean_freq,
                    obs.channel_good_frac, n_actions=n_actions,
                    kappa=p["kappa"], f_star=p["f_star"], v0=p["v0"],
                    v_growth=p["v_growth"])
                return jnp.argmax(s).astype(jnp.int32) + 1, state
            return step, False, pol_mp
        # generic (dqn, custom): one shared step closure, per-member carry
        # stacked — requires the step function to be member-independent
        # (all builtin dqn policies are: the net rides in the carry)
        base = pols[0]

        def step(state, obs, p):
            return base.step(state, obs)
        return step, base.needs_obs, None

    def _ctl_state(self):
        """The stacked policy carry, re-fetched from the member controllers
        each segment — exactly as the standalone `run_scanned` re-fetches
        ``scan_policy().state`` per call."""
        states = [f.controller.scan_policy().state
                  for f in self.federations]
        if not jax.tree_util.tree_leaves(states[0]):
            return states[0]
        ctl = jax.tree.map(lambda *ls: jnp.stack(ls), *states)
        if self.mesh is not None:
            sh = NamedSharding(self.mesh, PartitionSpec(self.pop_axis))
            ctl = jax.tree.map(lambda l: jax.device_put(l, sh), ctl)
        return ctl

    # ------------------------------------------------------------------ #
    def _member_view(self, mp: Dict[str, Any]) -> _MemberView:
        """Bind one member's vmap-sliced leaves + lifted scalars to a
        duck-typed engine the inherited round methods run against."""
        e0 = self._proto
        s0 = e0.spec
        spec = dataclasses.replace(
            s0,
            lr=mp["lr"], iota=mp["iota"],
            clustering=dataclasses.replace(
                s0.clustering, alpha0=mp["alpha0"],
                alpha_growth=mp["alpha_growth"]),
            channel=dataclasses.replace(s0.channel,
                                        pkt_fail=mp["pkt_fail"]),
            privacy=dataclasses.replace(s0.privacy, noise=mp["noise"]))
        faults = (_FaultView(e0.faults, mp["flt"])
                  if e0.faults.active else e0.faults)
        aggregator = (_LiftedWeightedAggregator(
            s0.aggregator.use_kernel, mp["agg_uniform"])
            if self._lift_agg else e0.aggregator)
        return _MemberView(
            spec=spec, task=e0.task, faults=faults, aggregator=aggregator,
            _sentinel=e0._sentinel, _n_actions=e0._n_actions,
            _padded=True, _fused_global=e0._fused_global,
            _member_table=mp["member_table"],
            _member_mask=mp["member_mask"],
            _part_idx=mp["part_idx"], _part_len=mp["part_len"],
            _x=mp["x"], _y=mp["y"],
            _malicious_dev=mp["malicious"],
            _misbehaving_dev=mp["misbehaving"],
            _trans=mp["trans"], _queue_per_slot=mp["per_slot"])

    def _build_scan_fn(self, K: int):
        pol_step = self._pol_step
        needs_obs = self._pol_needs_obs

        def member_body(state, times, ctl, energy, mp):
            view = self._member_view(mp)
            c = jnp.argmin(times).astype(jnp.int32)
            t = times[c]
            feats = view._ctl_features(state, c)
            obs48 = (view._scan_obs(state, c, feats) if needs_obs
                     else jnp.zeros((OBS_DIM,), jnp.float32))
            cobs = ctl_policy.CtlObs(
                round=state.round, cluster=c, queue=state.queue,
                cluster_loss=feats["cluster_loss"],
                cluster_freq=feats["cluster_freq"],
                mean_freq=feats["mean_freq"],
                channel_good_frac=feats["channel_good_frac"],
                energy_used=energy, dqn_obs=obs48)
            a_raw, ctl = pol_step(ctl, cobs, mp.get("pol"))
            state, m = view._fleet_round(
                state, c, a_raw, view._member_table[c],
                view._member_mask[c])
            times = times.at[c].set(t + m["dur"])
            energy = energy + m["consumed"]
            ys = {"t": t, "cluster": c, "a": m["a"], "dur": m["dur"],
                  "consumed": m["consumed"], "loss": m["loss"]}
            return (state, times, ctl, energy), ys

        vbody = jax.vmap(member_body, in_axes=(0, 0, 0, 0, 0))
        mp = self._mp

        def body(carry, _):
            state, times, ctl, energy = carry
            return vbody(state, times, ctl, energy, mp)

        def run_k(state, times, ctl, energy):
            return jax.lax.scan(body, (state, times, ctl, energy), None,
                                length=K)

        jit_kw = dict(
            donate_argnums=(0,) if jax.default_backend() != "cpu" else ())
        if self.mesh is not None:
            pop = NamedSharding(self.mesh, PartitionSpec(self.pop_axis))
            carry_sh = (jax.tree.map(lambda _: pop, self.state), pop,
                        jax.tree.map(lambda _: pop, self._ctl_state()),
                        pop)
            ys_sh = {k: NamedSharding(self.mesh,
                                      PartitionSpec(None, self.pop_axis))
                     for k in ("t", "cluster", "a", "dur", "consumed",
                               "loss")}
            jit_kw.update(in_shardings=carry_sh,
                          out_shardings=(carry_sh, ys_sh))
        return jax.jit(run_k, **jit_kw)

    # ------------------------------------------------------------------ #
    def set_member_sink(self, b: int, sink, *, retain: bool = True) -> None:
        """Attach a per-member trace sink (e.g. a run-dir `JsonlSink`)."""
        self._sinks[b] = sink
        self._retain[b] = retain

    def run_scanned(self, K: int, *,
                    eval_final: bool = True) -> List[FLTrace]:
        """Run K rounds of every member in one scan; per-member traces.

        Consecutive calls continue (times/energy/round counters carry), so
        segment sequences match one long run — the invariant the pool
        supervisor checkpoints on, inherited from the standalone engine."""
        K = int(K)
        energy0 = jnp.asarray([np.float32(e) for e in self._energy_used],
                              jnp.float32)
        if self.mesh is not None:
            energy0 = jax.device_put(energy0, NamedSharding(
                self.mesh, PartitionSpec(self.pop_axis)))
        args = (self.state, self._scan_times, self._ctl_state(), energy0)
        fn = self._scan_cache.get(K)
        if fn is None:
            fn = self._build_scan_fn(K)
            self._scan_cache[K] = fn
        (state, times, _, _), ys = fn(*args)
        self.state = state
        self._scan_times = times
        return self._emit(ys, K, eval_final)

    def _emit(self, ys, K: int, eval_final: bool) -> List[FLTrace]:
        ys = jax.device_get(ys)             # leaves (K, B); one host sync
        queue_host = None
        traces = []
        for b in range(self.B):
            base = self._rounds[b]
            self._rounds[b] += K
            # per-member exact-f64 energy: the same sequential additions
            # the standalone `_emit_scanned_trace` performs
            cum = []
            for ci in np.asarray(ys["consumed"][:, b], np.float32):
                self._energy_used[b] += float(ci)
                cum.append(self._energy_used[b])
            sync_queue = getattr(self.federations[b].controller,
                                 "sync_queue", None)
            if sync_queue is not None:
                if queue_host is None:
                    queue_host = jax.device_get(self.state.queue)
                sync_queue(queue_host[b])
            trace = FLTrace(records=[], sink=self._sinks[b],
                            retain=self._retain[b])
            for i in range(K):
                trace.append(RoundRecord(
                    t=float(ys["t"][i, b]), round=base + i + 1,
                    cluster=int(ys["cluster"][i, b]),
                    a=int(ys["a"][i, b]), loss=float(ys["loss"][i, b]),
                    acc=None, energy=cum[i], agg_count=base + i + 1))
            if eval_final:
                params_b = jax.tree.map(lambda l: l[b],
                                        self.state.global_params)
                ev = self.task.evaluate(params_b,
                                        self.federations[b].engine.data)
                trace.append(RoundRecord(
                    t=float(ys["t"][-1, b]) + float(ys["dur"][-1, b]),
                    round=self._rounds[b],
                    cluster=int(ys["cluster"][-1, b]),
                    a=int(ys["a"][-1, b]), loss=ev["loss"],
                    acc=ev.get("acc"), energy=self._energy_used[b],
                    agg_count=self._rounds[b]))
            traces.append(trace)
        return traces

    # ------------------------------------------------------------------ #
    # per-member serve surface (checkpoint/resume interop with repro.serve)
    # ------------------------------------------------------------------ #
    def member(self, b: int) -> "PopulationMember":
        return PopulationMember(self, int(b))

    def member_rounds(self, b: int) -> int:
        return self._rounds[b]

    def member_energy(self, b: int) -> float:
        return self._energy_used[b]

    def _member_resumable(self, b: int) -> dict:
        fleet = jax.tree.map(lambda l: l[b], self.state)
        return {"fleet": fleet, "times": self._scan_times[b]}

    def _restore_member(self, b: int, tree: dict, *, rounds: int,
                        energy: float) -> None:
        fleet = tree["fleet"]
        self.state = jax.tree.map(
            lambda L, l: L.at[b].set(jnp.asarray(l)), self.state, fleet)
        self._scan_times = self._scan_times.at[b].set(
            jnp.asarray(tree["times"], jnp.float32))
        self._rounds[b] = int(rounds)
        self._energy_used[b] = float(energy)


class _MemberEngineView:
    """The engine half of a `PopulationMember`: exposes exactly the
    resumable surface `repro.serve.runner` drives, backed by slices of the
    stacked population state — so member checkpoints are byte-compatible
    with single-tenant `repro.serve` run dirs."""

    def __init__(self, pop: PopulationEngine, b: int):
        self._pop = pop
        self.b = b

    @property
    def spec(self):
        return self._pop.specs[self.b]

    @property
    def round(self) -> int:
        return self._pop.member_rounds(self.b)

    @property
    def energy_used(self) -> float:
        return self._pop.member_energy(self.b)

    def resumable_state(self) -> dict:
        return self._pop._member_resumable(self.b)

    def restore_resumable(self, tree: dict, *, rounds: int,
                          energy: float) -> None:
        self._pop._restore_member(self.b, tree, rounds=rounds,
                                  energy=energy)


class PopulationMember:
    """A federation-shaped facade over one population slot — what
    `repro.serve.runner.save_resumable`/`restore_resumable` consume."""

    def __init__(self, pop: PopulationEngine, b: int):
        self.engine = _MemberEngineView(pop, b)
        self.controller = pop.federations[b].controller
        self.spec = pop.specs[b]
