"""`PopulationSpec`: a declarative sweep of `FederationSpec`s.

A population is B independent federations that share one *structure*
(shapes, component kinds, static fault gates) and vary in seeds and scalar
knobs — exactly what `repro.pop.engine.PopulationEngine` can vmap into a
single device program.  The spec layer mirrors `repro.api.spec`: a plain
dataclass with strict dict/JSON round-trip, expanded into registry-validated
member `FederationSpec`s by `expand()`.

Sweep axes compose two ways:

``grid``        dotted-field-path -> list of values; member cells are the
                cartesian product in key order (``{"lr": [...], "channel.
                pkt_fail": [...]}``).  Paths traverse nested spec
                dataclasses and the ``params`` dicts of component specs
                (``"controller.params.budget"``).
``replicates``  seed replicates per grid cell — the confidence-interval
                axis.

Per-member seeds derive from the base seed via `member_seed` (a
`jax.random.fold_in` fold of the member index — no ad-hoc ``seed + i``
arithmetic), so member *b* of a population is pinned bit-identical to a
standalone ``Federation.from_spec`` run of the expanded spec.
``derive_seeds=False`` keeps the base/grid seed verbatim instead (e.g. the
robustness grid, which sweeps aggregators *against* a fixed seed).

``sharding`` places the *population* axis on a 1-D mesh (axis name
defaults to "pop"); member specs themselves are always unsharded — the
population batch dim is the parallel axis.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.api.spec import FederationSpec, ShardingSpec, _from_dict, _NESTED

__all__ = ["PopulationSpec", "member_seed"]

POP_AXIS = "pop"                 # default mesh axis name for the batch dim


def member_seed(base_seed: int, b: int) -> int:
    """The seed of population member ``b``: a `fold_in` of the member index
    into the base seed's key, reduced to a plain non-negative int32.

    Returns an ordinary Python int so the derived seed is consumable
    anywhere a spec seed is — a standalone ``Federation.from_spec`` run
    with ``seed=member_seed(base, b)`` is the bit-parity reference for
    member ``b`` of the population."""
    key = jax.random.fold_in(jax.random.key(int(base_seed)), int(b))
    return int(jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max))


def _apply_override(obj, path: str, value):
    """Set a dotted field path on a nested dataclass/dict tree, returning
    a replaced copy (the original spec is never mutated)."""
    head, _, rest = path.partition(".")
    if isinstance(obj, dict):
        if rest and head not in obj:
            raise KeyError(f"grid path {path!r}: no key {head!r} in dict")
        out = dict(obj)
        out[head] = _apply_override(obj[head], rest, value) if rest \
            else value
        return out
    if not dataclasses.is_dataclass(obj):
        raise TypeError(f"grid path {path!r}: cannot descend into "
                        f"{type(obj).__name__}")
    names = {f.name for f in dataclasses.fields(obj)}
    if head not in names:
        raise KeyError(f"grid path {path!r}: {type(obj).__name__} has no "
                       f"field {head!r}; valid: {sorted(names)}")
    new = _apply_override(getattr(obj, head), rest, value) if rest else value
    return dataclasses.replace(obj, **{head: new})


@dataclasses.dataclass
class PopulationSpec:
    """B federations from one base spec + sweep axes (module docstring)."""
    base: FederationSpec
    grid: Dict[str, List[Any]] = dataclasses.field(default_factory=dict)
    replicates: int = 1
    derive_seeds: bool = True
    sharding: ShardingSpec = dataclasses.field(default_factory=ShardingSpec)

    # ------------------------------------------------------------------ #
    @property
    def size(self) -> int:
        n = self.replicates
        for values in self.grid.values():
            n *= len(values)
        return n

    def validate(self) -> "PopulationSpec":
        if self.replicates < 1:
            raise ValueError(f"population: replicates={self.replicates} "
                             "must be >= 1")
        for path, values in self.grid.items():
            if not isinstance(values, (list, tuple)) or not len(values):
                raise ValueError(f"population: grid[{path!r}] must be a "
                                 "non-empty list of values")
        if self.sharding.is_sharded:
            if len(self.sharding.mesh) != 1:
                raise ValueError(
                    f"population: sharding shards the population axis only "
                    f"(1-D mesh); got mesh {self.sharding.mesh}")
            shards = self.sharding.mesh[0]
            if self.size % shards:
                raise ValueError(
                    f"population: mesh has {shards} shards, which does not "
                    f"divide the population size {self.size}")
        if self.base.sharding.is_sharded:
            raise ValueError(
                "population: the base spec must be unsharded — the "
                "population batch axis is the parallel dim (set sharding "
                "on the PopulationSpec instead)")
        self.base.validate()
        return self

    def pop_axis(self) -> str:
        axes = self.sharding.axes
        return axes[0] if axes else POP_AXIS

    # ------------------------------------------------------------------ #
    def expand(self) -> List[FederationSpec]:
        """Member specs in population order: grid cells in cartesian
        product order (key order), replicates innermost; each validated."""
        self.validate()
        keys = list(self.grid)
        members: List[FederationSpec] = []
        for combo in itertools.product(*(self.grid[k] for k in keys)):
            cell = self.base
            for path, value in zip(keys, combo):
                cell = _apply_override(cell, path, value)
            for _ in range(self.replicates):
                b = len(members)
                spec = dataclasses.replace(cell, sharding=ShardingSpec())
                if self.derive_seeds and "seed" not in keys:
                    spec = dataclasses.replace(
                        spec, seed=member_seed(self.base.seed, b))
                members.append(spec.validate())
        return members

    # ------------------------------------------------------------------ #
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PopulationSpec":
        return _from_dict(cls, d, path="population")

    def replace(self, **kw) -> "PopulationSpec":
        return dataclasses.replace(self, **kw)


# strict hydration for the nested spec fields rides the same machinery as
# FederationSpec.from_dict
_NESTED[("PopulationSpec", "base")] = FederationSpec
_NESTED[("PopulationSpec", "sharding")] = ShardingSpec
