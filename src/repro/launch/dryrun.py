import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles against the production meshes.

For each combination this lowers + compiles the step, prints
memory_analysis() (proves it fits) and cost_analysis() (FLOPs/bytes for
EXPERIMENTS.md §Roofline), parses collective traffic from the optimized HLO,
and appends a JSON record to --out.

Usage:
    python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import all_arch_ids
from .hlo_stats import analyze_module, op_histogram
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, n_chips
from .plans import SHAPES, applicable, make_plan


def run_one(arch: str, shape: str, multi_pod: bool, verbose: bool = True):
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    skip = applicable(arch, shape)
    if skip:
        rec["status"] = skip
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(arch, shape, mesh)
    try:
        with mesh:
            jitted = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                             out_shardings=plan.out_shardings,
                             donate_argnums=plan.donate)
            lowered = jitted.lower(*plan.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        stats = analyze_module(hlo)          # loop-aware (hlo_stats.py)
        coll = dict(stats.collectives)
        coll["total"] = sum(stats.collectives.values())
        coll["count"] = stats.n_collective_ops
        hist = op_histogram(hlo)

        chips = n_chips(mesh)
        flops = stats.flops
        bytes_accessed = stats.bytes_traffic
        rec.update({
            "status": "ok",
            "kind": plan.kind,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "chips": chips,
            # memory_analysis is per-device on the host backend
            "bytes_per_device": {
                "argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "generated_code": mem.generated_code_size_in_bytes,
                "total": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                          + mem.temp_size_in_bytes),
            },
            # NOTE: all quantities are PER-DEVICE (SPMD module), loop-aware
            # via hlo_stats.analyze_module (XLA's own cost_analysis counts
            # while bodies once — verified — so it is kept only as a
            # reference field).  The §Roofline division by `chips` is thus
            # already applied: t = per_device_quantity / per_chip_rate.
            "hlo_flops_per_dev": flops,
            "hlo_bytes_per_dev": bytes_accessed,
            "xla_cost_flops_raw": float(cost.get("flops", 0.0)),
            "collectives": coll,
            "op_hist": hist,
            "t_compute": flops / PEAK_FLOPS_BF16,
            "t_memory": bytes_accessed / HBM_BW,
            "t_collective": coll["total"] / ICI_BW,
        })
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape}: OK "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
            print("  memory_analysis:", rec["bytes_per_device"])
            print(f"  cost_analysis (per-dev): flops={flops:.3e} bytes={bytes_accessed:.3e}")
            print(f"  collectives: {coll}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape}: FAILED — {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun_results.jsonl")
    args = ap.parse_args()

    archs = all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    records = []
    for a in archs:
        for s in shapes:
            rec = run_one(a, s, args.multi_pod)
            records.append(rec)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    ok = sum(r["status"] == "ok" for r in records)
    skipped = sum(r["status"].startswith("skip") for r in records)
    print(f"\n{ok} ok / {skipped} skipped / "
          f"{len(records) - ok - skipped} failed of {len(records)}")


if __name__ == "__main__":
    main()
