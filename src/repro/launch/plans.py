"""Lowering plans: (architecture x input shape x mesh) -> step fn + abstract
inputs + shardings.

Used by dryrun.py (compile-only, ShapeDtypeStruct stand-ins, no allocation)
and by train.py/serve.py (real arrays at example scale).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config
from ..core import fl_step as fl
from ..models import (ArchConfig, cache_specs, init_cache, init_params,
                      param_specs, prefill)
from ..optim import adafactor
from .mesh import axis_size

SHAPES = {
    "train_4k":    dict(kind="train",   seq=4096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq=32768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq=524288, global_batch=1),
}

# archs whose long_500k is inapplicable (pure full attention, no declared
# sliding-window variant — DESIGN.md §4)
LONG_SKIP_REASON = "skipped(full-attn)"


@dataclasses.dataclass
class Plan:
    arch: str
    shape: str
    kind: str
    step_fn: Callable            # jit-able
    args: tuple                  # abstract (ShapeDtypeStruct) or concrete args
    in_shardings: tuple
    out_shardings: Any           # pytree or None
    cfg: ArchConfig
    donate: tuple = ()           # donated arg indices (state / cache aliasing)
    skip: Optional[str] = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_struct(cfg, lead, batch, seq):
    if cfg.num_codebooks > 1:
        return _sds(lead + (batch, cfg.num_codebooks, seq), jnp.int32)
    return _sds(lead + (batch, seq), jnp.int32)


# --------------------------------------------------------------------- #
def applicable(arch_id: str, shape_name: str) -> Optional[str]:
    """None if runnable, else a skip reason."""
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and not cfg.subquadratic \
            and cfg.sliding_variant_window <= 0:
        return LONG_SKIP_REASON
    return None


def train_plan(arch_id: str, shape_name: str, mesh,
               param_dtype=jnp.bfloat16) -> Plan:
    cfg = get_config(arch_id)
    spec = SHAPES[shape_name]
    seq, gbatch = spec["seq"], spec["global_batch"]
    n_pods = axis_size(mesh, "pod")
    n_data = axis_size(mesh, "data")
    tp_size = axis_size(mesh, "model")
    pod_axis = "pod" if n_pods > 1 else None
    mode = cfg.fl_mode
    NC = max(n_pods, 1)

    big = cfg.param_count() * (2 if param_dtype == jnp.bfloat16 else 4) \
        > 30e9 * 2
    accum_dtype = jnp.bfloat16 if big else jnp.float32
    # sequential leaf updates + bf16 update math bound optimizer temps
    opt = adafactor(1e-2, sequential=big,
                    compute_dtype=jnp.bfloat16 if big else None)
    q_chunk = (512 if big else 1024) if seq >= 4096 else 0

    if mode == fl.MODE_A:
        C = n_data
        # Bm=4 per microbatch: weights stream once per micro-step, so fewer
        # micro-steps cut HBM traffic ~linearly while remat keeps the
        # activation footprint bounded (§Perf pair 3, iter 3)
        per_client = max(1, gbatch // (NC * C))
        bm = min(2, per_client)   # Bm=4 breached HBM (16.3 GB); 2 balances
        n_micro = max(1, per_client // bm)
        lead = (NC, C, n_micro, bm)
        batch = {"tokens": _token_struct(cfg, lead[:-1], bm, seq),
                 "labels": _token_struct(cfg, lead[:-1], bm, seq)}
    else:
        bm = n_data
        n_micro = max(1, gbatch // (NC * bm))
        lead = (NC, n_micro, bm)
        batch = {"tokens": _token_struct(cfg, lead[:-1], bm, seq),
                 "labels": _token_struct(cfg, lead[:-1], bm, seq),
                 "weights": _sds((NC, n_micro, bm), jnp.float32)}

    init_fn = fl.build_init_fn(cfg, opt, mode=mode, n_clusters=NC,
                               clients_per_cluster=n_data, dtype=param_dtype)
    state_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    state_specs = fl.train_state_specs(cfg, state_shapes, mode=mode,
                                       opt_name="adafactor",
                                       pod_axis=pod_axis, tp_size=tp_size)
    batch_sp = fl.batch_specs(cfg, batch, mode=mode, pod_axis=pod_axis)
    rep = _sds((NC, n_data if mode == fl.MODE_A else 1), jnp.float32)
    stale = _sds((NC,), jnp.float32)

    step = fl.build_train_step(cfg, opt, mode=mode, local_steps=1,
                               q_chunk=q_chunk, accum_dtype=accum_dtype)
    ns = lambda s: jax.tree.map(lambda x: NamedSharding(mesh, x), s,
                                is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(state_specs), ns(batch_sp), ns(P(None, None)), ns(P(None)))
    out_sh = (ns(state_specs), None)
    return Plan(arch_id, shape_name, "train", step,
                (state_shapes, batch, rep, stale), in_sh, out_sh, cfg,
                donate=(0,))


def _serve_cfg(arch_id: str, shape_name: str) -> ArchConfig:
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and not cfg.subquadratic:
        cfg = cfg.long_context_variant()
    return cfg


def _serve_param_specs(cfg, tp_size):
    fsdp = "data" if cfg.shard_scheme in ("ep_tp", "fsdp_tp") else None
    stack_axis = "data" if cfg.shard_scheme == "stack_tp" else None
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    specs = param_specs(shapes, cfg, tp="model", fsdp=fsdp,
                        stack_axis=stack_axis, tp_size=tp_size)
    return shapes, specs


def _cache_layout(cfg, batch, n_data, tp_size=16):
    batch_axis = "data" if batch % n_data == 0 and batch >= n_data else None
    kv_ok = (cfg.num_kv_heads > 1 and not cfg.use_mla
             and cfg.num_kv_heads % tp_size == 0)
    kv_axis = "model" if kv_ok else None
    # kv-head count indivisible by the model axis: context-parallel KV cache
    attn_seq_axis = ("model" if (cfg.num_kv_heads > 1 and not cfg.use_mla
                                 and not kv_ok) else None)
    seq_axis = "model" if cfg.use_mla else None
    return dict(batch_axis=batch_axis, kv_axis=kv_axis, seq_axis=seq_axis,
                state_axis="model", attn_seq_axis=attn_seq_axis)


def decode_plan(arch_id: str, shape_name: str, mesh,
                param_dtype=jnp.bfloat16) -> Plan:
    skip = applicable(arch_id, shape_name)
    cfg = _serve_cfg(arch_id, shape_name)
    spec = SHAPES[shape_name]
    seq, batch = spec["seq"], spec["global_batch"]
    n_data = axis_size(mesh, "data")
    tp_size = axis_size(mesh, "model")

    pshapes, pspecs = _serve_param_specs(cfg, tp_size)
    cache_shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, seq))
    layout = _cache_layout(cfg, batch, n_data, tp_size)
    cspecs = cache_specs(cache_shapes, **layout)

    if cfg.num_codebooks > 1:
        tok = _sds((batch, cfg.num_codebooks), jnp.int32)
        tok_spec = P(layout["batch_axis"], None)
    else:
        tok = _sds((batch,), jnp.int32)
        tok_spec = P(layout["batch_axis"])
    step_pos = _sds((), jnp.int32)

    step = fl.build_serve_step(cfg)
    ns = lambda s: jax.tree.map(lambda x: NamedSharding(mesh, x), s,
                                is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(pspecs), ns(cspecs), ns(tok_spec), ns(P()))
    out_sh = (None, ns(cspecs))
    return Plan(arch_id, shape_name, "decode", step,
                (pshapes, cache_shapes, tok, step_pos), in_sh, out_sh, cfg,
                donate=(1,), skip=skip)


def prefill_plan(arch_id: str, shape_name: str, mesh,
                 param_dtype=jnp.bfloat16) -> Plan:
    cfg = _serve_cfg(arch_id, shape_name)
    spec = SHAPES[shape_name]
    seq, batch = spec["seq"], spec["global_batch"]
    n_data = axis_size(mesh, "data")
    tp_size = axis_size(mesh, "model")

    pshapes, pspecs = _serve_param_specs(cfg, tp_size)
    layout = _cache_layout(cfg, batch, n_data, tp_size)
    cache_shapes = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, seq))
    cspecs = cache_specs(cache_shapes, **layout)
    tok = _token_struct(cfg, (), batch, seq)
    tok_spec = P(*([layout["batch_axis"]] + [None] * (tok.ndim - 1)))

    def prefill_step(params, tokens):
        return prefill(params, cfg, tokens, cache_len=seq, q_chunk=1024)

    ns = lambda s: jax.tree.map(lambda x: NamedSharding(mesh, x), s,
                                is_leaf=lambda x: isinstance(x, P))
    in_sh = (ns(pspecs), ns(tok_spec))
    out_sh = (None, ns(cspecs))
    return Plan(arch_id, shape_name, "prefill", prefill_step,
                (pshapes, tok), in_sh, out_sh, cfg)


def make_plan(arch_id: str, shape_name: str, mesh) -> Plan:
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return train_plan(arch_id, shape_name, mesh)
    if kind == "prefill":
        return prefill_plan(arch_id, shape_name, mesh)
    return decode_plan(arch_id, shape_name, mesh)
