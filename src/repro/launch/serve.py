"""Decode-serving driver: prefill a batch of prompts, then step the
sharded decode loop with the ring-buffer KV / recurrent-state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --prompt-len 32 --gen 32 --batch 4 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import build_serve_step
from ..data import token_stream
from ..models import init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    max_len = args.prompt_len + args.gen

    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape = (args.batch, cfg.num_codebooks, args.prompt_len)
    prompts = token_stream(key, int(np.prod(shape)), cfg.vocab_size
                           ).reshape(shape)

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: prefill(p, cfg, t, cache_len=max_len, q_chunk=16)
    )(params, prompts)
    jax.block_until_ready(logits)
    print(f"prefill,{args.batch}x{args.prompt_len},{time.time()-t0:.2f}s")

    step = jax.jit(build_serve_step(cfg))
    tok = jnp.argmax(logits, axis=-1)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        key, ks = jax.random.split(key)
        logits, cache = step(params, cache, tok, jnp.int32(args.prompt_len + i))
        if args.temperature > 0:
            tok = jax.random.categorical(ks, logits / args.temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    ntok = (args.gen - 1) * args.batch
    print(f"decode,{ntok}_tokens,{dt:.2f}s,{ntok/max(dt,1e-9):.1f}tok/s")
    gen = jnp.stack(out, axis=-1)
    print("sample_ids:", np.asarray(gen)[0].reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
