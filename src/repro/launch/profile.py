import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run profiler for §Perf iterations: lowers one (arch x shape), prints
the roofline terms, the loop-weighted traffic breakdown by op kind, and the
hottest loops.  This is the 'profile' the hypothesis->change->measure cycles
read (no wall clock on CPU).

    PYTHONPATH=src python -m repro.launch.profile --arch falcon-mamba-7b \
        --shape train_4k [--save /tmp/x.hlo]
"""
import argparse

import jax

from .hlo_stats import analyze_module, loop_summary, traffic_breakdown
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from .plans import make_plan


def profile(arch, shape, multi_pod=False, save=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(arch, shape, mesh)
    with mesh:
        j = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                    out_shardings=plan.out_shardings,
                    donate_argnums=plan.donate)
        compiled = j.lower(*plan.args).compile()
    txt = compiled.as_text()
    if save:
        with open(save, "w") as f:
            f.write(txt)
    st = analyze_module(txt)
    mem = compiled.memory_analysis()
    coll_total = sum(st.collectives.values())
    print(f"== {arch} x {shape} ({'2x16x16' if multi_pod else '16x16'}) ==")
    print(f"t_compute    {st.flops / PEAK_FLOPS_BF16:10.3f}s   "
          f"({st.flops:.3e} flop/dev)")
    print(f"t_memory     {st.bytes_traffic / HBM_BW:10.3f}s   "
          f"({st.bytes_traffic:.3e} B/dev)")
    print(f"t_collective {coll_total / ICI_BW:10.3f}s   ({coll_total:.3e} B/dev)")
    print(f"mem/dev: arg {mem.argument_size_in_bytes/1e9:.1f} + temp "
          f"{mem.temp_size_in_bytes/1e9:.1f} GB")
    print("collectives:", {k: f"{v:.2e}" for k, v in st.collectives.items()
                           if v})
    print("\ntraffic by op kind (loop-weighted):")
    for k, v in traffic_breakdown(txt).items():
        print(f"  {k:<22} {v:.3e} B  ({v/HBM_BW:8.3f}s)")
    print("\nhottest loops (trip, per-iter B, total B):")
    for trip, per, tot, name in loop_summary(txt):
        print(f"  x{trip:<6} {per:.2e} -> {tot:.3e}  {name}")
    return st, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--save", default=None)
    args = ap.parse_args()
    profile(args.arch, args.shape, args.multi_pod, args.save)


if __name__ == "__main__":
    main()
