"""Loop-aware HLO analysis: roofline terms from the compiled SPMD module.

XLA's ``cost_analysis()`` counts each ``while`` body ONCE regardless of trip
count (verified empirically), which would understate FLOPs/bytes/collectives
for scan-over-layers and microbatch-accumulation loops by 10-100x.  This
module re-derives loop-aware totals by walking the optimized HLO text:

  * computations are parsed into (def -> shape) tables;
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    bodies are recursively analyzed with multiplier x trip_count;
  * FLOPs: 2 x prod(result dims) x prod(contracted dims) per ``dot``
    (elementwise flops are ignored — matmuls dominate every assigned arch);
  * HBM-traffic proxy: result bytes + resolvable operand bytes of every
    substantive op (parameters/gte/bitcast/tuple are free);
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async -start counted,
    -done skipped), multiplied by loop nesting.

All numbers are PER-DEVICE (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from typing import Dict, List, NamedTuple, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "bitcast", "tuple",
             "after-all", "iota", "partition-id", "replica-id", "domain",
             "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:condition|body|to_apply|called_computations)="
                       r"\{?%?([\w\.\-]+)\}?")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    """All array shapes in a (possibly tuple) type string."""
    return [(dt, [int(d) for d in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_RE.findall(type_str)]


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES.get(dt, 4) * (int(np_prod(dims)) if dims else 1)
               for dt, dims in _shape_list(type_str))


def np_prod(dims):
    out = 1
    for d in dims:
        out *= d
    return out


class Instr(NamedTuple):
    name: str
    opcode: str
    type_str: str      # result type portion of the line
    line: str


class Computation(NamedTuple):
    name: str
    instrs: List[Instr]
    defs: Dict[str, str]          # name -> result type string


class ModuleStats(NamedTuple):
    flops: float
    bytes_traffic: float
    collectives: Dict[str, float]
    n_collective_ops: float


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur_name: Optional[str] = None
    instrs: List[Instr] = []
    defs: Dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur_name is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*->.*\{\s*$",
                         line)
            # exclude instruction lines ("%x = ..."); note `/*index=5*/`
            # comments inside header param lists contain '=' without spaces
            if m and " = " not in line.split("->")[0]:
                cur_name = m.group(1)
                instrs, defs = [], {}
            continue
        if line == "}":
            comps[cur_name] = Computation(cur_name, instrs, defs)
            cur_name = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # result type = prefix of rest up to the opcode token
        om = _OPCODE_RE.match(rest)
        opcode = om.group(1) if om else rest.split()[0]
        tm = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[\d,]*\]\S*)", rest)
        type_str = tm.group(1) if tm else ""
        defs[name] = type_str
        instrs.append(Instr(name, opcode, type_str, line))
    return comps


def _dot_flops(instr: Instr, defs: Dict[str, str]) -> float:
    out_dims = _shape_list(instr.type_str)
    if not out_dims:
        return 0.0
    n_out = np_prod(out_dims[0][1])
    cm = _CONTRACT_RE.search(instr.line)
    # first operand name after the opcode '('
    paren = instr.line.split(f"{instr.opcode}(", 1)
    contract = 1
    if cm and len(paren) == 2:
        ops = _OPERAND_RE.findall(paren[1])
        if ops and ops[0] in defs:
            lhs = _shape_list(defs[ops[0]])
            if lhs:
                dims = lhs[0][1]
                for i in (int(x) for x in cm.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * n_out * contract


def _nth_operand_bytes(instr: Instr, defs: Dict[str, str], n: int) -> int:
    paren = instr.line.split(f"{instr.opcode}(", 1)
    if len(paren) != 2:
        return 0
    args = paren[1].split("), ")[0]
    names = _OPERAND_RE.findall(args)
    if len(names) > n and names[n] in defs:
        return _bytes_of(defs[names[n]])
    return 0


_SLICING = {"dynamic-slice", "gather"}


def _fusion_read_bytes(instr: Instr, defs: Dict[str, str],
                       comps: Dict[str, "Computation"]) -> int:
    """HBM reads of a fusion: each operand costs its full size UNLESS every
    interior use of the corresponding parameter is a slicing op — then only
    the sliced bytes are read (this is what keeps scan-over-layers honest:
    the stacked (L, ...) weights are dynamic-sliced per iteration, not
    re-read wholesale)."""
    m = re.search(r"calls=%?([\w\.\-]+)", instr.line)
    inner = comps.get(m.group(1)) if m else None
    paren = instr.line.split("fusion(", 1)
    if len(paren) != 2:
        return 0
    args = paren[1].split("), ")[0]
    operand_names = _OPERAND_RE.findall(args)
    if inner is None:
        return sum(_bytes_of(defs[n]) for n in operand_names if n in defs)

    # parameter index -> interior name
    param_names = {}
    for ins2 in inner.instrs:
        if ins2.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", ins2.line)
            if pm:
                param_names[int(pm.group(1))] = ins2.name
    total = 0
    for idx, outer in enumerate(operand_names):
        pname = param_names.get(idx)
        outer_bytes = _bytes_of(defs.get(outer, "")) if outer in defs else 0
        if pname is None:
            total += outer_bytes
            continue
        uses = [u for u in inner.instrs
                if re.search(rf"%{re.escape(pname)}\b", u.line.split("=", 1)[-1])
                and u.name != pname]
        if uses and all(u.opcode in _SLICING for u in uses):
            total += sum(_bytes_of(u.type_str) for u in uses)
        else:
            total += outer_bytes
    return total


def _operand_bytes(instr: Instr, defs: Dict[str, str]) -> int:
    paren = instr.line.split(f"{instr.opcode}(", 1)
    if len(paren) != 2:
        return 0
    total = 0
    # operands end at the matching close paren; regex over the args segment
    args = paren[1].split("), ")[0]
    for name in _OPERAND_RE.findall(args):
        if name in defs:
            total += _bytes_of(defs[name])
    return total


def analyze_module(text: str) -> ModuleStats:
    comps = parse_module(text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m:
        entry = m.group(1)
    if entry not in comps:
        # fall back: the computation named like main
        entry = next((n for n in comps if "main" in n), None)
    if entry is None:
        return ModuleStats(0.0, 0.0, {k: 0.0 for k in COLLECTIVES}, 0.0)

    from functools import lru_cache

    def walk(comp_name: str) -> Tuple[float, float, Dict[str, float], float]:
        comp = comps.get(comp_name)
        if comp is None:
            return 0.0, 0.0, {k: 0.0 for k in COLLECTIVES}, 0.0
        flops = 0.0
        traffic = 0.0
        coll = {k: 0.0 for k in COLLECTIVES}
        ncoll = 0.0
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE_OPS:
                continue
            base = op.replace("-start", "")
            if op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                b = _bytes_of(ins.type_str)
                coll[base] += b
                traffic += b
                ncoll += 1
                continue
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(ins.line)
                if bm:
                    f, t, c, n = walk_cached(bm.group(1))
                    flops += trip * f
                    traffic += trip * t
                    ncoll += trip * n
                    for k in COLLECTIVES:
                        coll[k] += trip * c[k]
                traffic += _bytes_of(ins.type_str)
                continue
            if op in ("call", "conditional", "async-start"):
                cm2 = _CALLS_RE.search(ins.line)
                if cm2:
                    f, t, c, n = walk_cached(cm2.group(1))
                    flops += f
                    traffic += t
                    ncoll += n
                    for k in COLLECTIVES:
                        coll[k] += c[k]
                continue
            if op in ("dot", "convolution"):
                flops += _dot_flops(ins, comp.defs)
            # HBM-traffic model: slicing ops move only the slice, and
            # dynamic-update-slice aliases its buffer in place (reads+writes
            # the update window, not the whole operand).
            if op in ("dynamic-slice", "gather"):
                traffic += 2 * _bytes_of(ins.type_str)
            elif op in ("dynamic-update-slice", "scatter"):
                upd = _nth_operand_bytes(ins, comp.defs, 1)
                traffic += 2 * (upd if upd else _bytes_of(ins.type_str))
            elif op == "fusion":
                traffic += _bytes_of(ins.type_str)
                traffic += _fusion_read_bytes(ins, comp.defs, comps)
            else:
                traffic += _bytes_of(ins.type_str) + _operand_bytes(ins, comp.defs)
        return flops, traffic, coll, ncoll

    @lru_cache(maxsize=None)
    def walk_cached(name: str):
        return walk(name)

    f, t, c, n = walk_cached(entry)
    return ModuleStats(f, t, c, n)


def traffic_breakdown(text: str, top: int = 12) -> Dict[str, float]:
    """Loop-weighted HBM-traffic by op kind — the dry-run 'profile' used by
    the §Perf iterations (no wall-clock on CPU; this is what we optimize)."""
    comps = parse_module(text)
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    entry = m.group(1) if m else None
    out: Dict[str, float] = {}

    def add(kind, b):
        out[kind] = out.get(kind, 0.0) + b

    def walk(comp_name, mult):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                add(base, mult * _bytes_of(ins.type_str))
                continue
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(ins.line)
                if bm:
                    walk(bm.group(1), mult * trip)
                continue
            if op in ("call", "conditional"):
                cm2 = _CALLS_RE.search(ins.line)
                if cm2:
                    walk(cm2.group(1), mult)
                continue
            if op in ("dynamic-slice", "gather"):
                add(op, mult * 2 * _bytes_of(ins.type_str))
            elif op in ("dynamic-update-slice", "scatter"):
                upd = _nth_operand_bytes(ins, comp.defs, 1)
                add(op, mult * 2 * (upd or _bytes_of(ins.type_str)))
            elif op == "fusion":
                add(op, mult * (_bytes_of(ins.type_str)
                                + _fusion_read_bytes(ins, comp.defs, comps)))
            else:
                add(op, mult * (_bytes_of(ins.type_str)
                                + _operand_bytes(ins, comp.defs)))

    if entry:
        walk(entry, 1.0)
    return dict(sorted(out.items(), key=lambda kv: -kv[1])[:top])


def loop_summary(text: str):
    """(trip_count, per-iteration traffic, total) per while loop — finds the
    seq-scan hot loops."""
    comps = parse_module(text)
    rows = []
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode != "while":
                continue
            tm = _TRIP_RE.search(ins.line)
            trip = int(tm.group(1)) if tm else 1
            bm = _BODY_RE.search(ins.line)
            if not bm:
                continue
            body = comps.get(bm.group(1))
            if body is None:
                continue
            per = 0
            for bins in body.instrs:
                if bins.opcode in _FREE_OPS:
                    continue
                per += _bytes_of(bins.type_str)
            rows.append((trip, per, trip * per, bm.group(1)[:40]))
    rows.sort(key=lambda r: -r[2])
    return rows[:10]


def collective_bytes(text: str) -> Dict[str, float]:
    st = analyze_module(text)
    out = dict(st.collectives)
    out["total"] = sum(st.collectives.values())
    out["count"] = st.n_collective_ops
    return out


def op_histogram(hlo_text: str, ops=("fusion", "all-gather", "all-reduce",
                                     "reduce-scatter", "all-to-all",
                                     "collective-permute", "copy",
                                     "transpose", "while")) -> Dict[str, int]:
    hist = {}
    for op in ops:
        hist[op] = len(re.findall(rf"\s{re.escape(op)}[.(]", hlo_text))
    return hist
