"""`jax.distributed` bring-up for multi-process CPU fleets.

Multi-controller SPMD on plain CPUs: every process runs the *same*
program, contributes ``REPRO_DIST_LOCAL_DEVICES`` forced-host CPU devices
to one global mesh, and the cluster-major shard_map round's two psums run
as real cross-process collectives (gloo).  Worker processes must call
:func:`initialize_from_env` **before importing jax-heavy modules** — it
appends ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``,
which XLA reads once at backend init.

    # parent: spawn 2 workers of this very script
    from repro.launch.distributed import spawn_local
    results = spawn_local([sys.argv[0], "--dist-worker"], n_procs=2,
                          local_devices=2)

    # worker (top of the script, before `import jax`):
    from repro.launch.distributed import initialize_from_env
    initialize_from_env()

The env-var contract (``REPRO_DIST_COORD`` / ``_NPROC`` / ``_PID`` /
``_LOCAL_DEVICES``) also works under an external launcher (mpirun, srun,
k8s indexed jobs): export the four variables per rank and call
:func:`initialize_from_env` — no CLI coupling.

This module deliberately does not import jax at module scope, so it is
importable before the worker's XLA_FLAGS are final.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import List, Optional, Sequence

ENV_COORD = "REPRO_DIST_COORD"            # host:port of process 0
ENV_NPROC = "REPRO_DIST_NPROC"            # total process count
ENV_PID = "REPRO_DIST_PID"                # this process's rank
ENV_LOCAL = "REPRO_DIST_LOCAL_DEVICES"    # forced-host devices per process


def initialize_from_env() -> Optional[int]:
    """Join the distributed runtime described by the REPRO_DIST_* env.

    No-op (returns None) when ``REPRO_DIST_COORD`` is unset, so worker
    entry points can call this unconditionally and still run
    single-process.  Returns the process id after
    ``jax.distributed.initialize``.
    """
    coord = os.environ.get(ENV_COORD)
    if coord is None:
        return None
    nproc = int(os.environ[ENV_NPROC])
    pid = int(os.environ[ENV_PID])
    local = int(os.environ.get(ENV_LOCAL, "1"))
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={local}"
        ).strip()

    import jax

    # cross-process CPU collectives ride on gloo; leave the default in
    # place on jaxlibs that pick the implementation themselves
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - jaxlib without the option
        pass
    jax.distributed.initialize(coordinator_address=coord,
                               num_processes=nproc, process_id=pid)
    return pid


def free_port() -> int:
    """An OS-assigned free TCP port (release-then-reuse: fine for a
    localhost coordinator started immediately after)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_local(argv: Sequence[str], n_procs: int = 2,
                local_devices: int = 1, coordinator: Optional[str] = None,
                timeout: float = 1200.0,
                env: Optional[dict] = None) -> List[subprocess.CompletedProcess]:
    """Run ``n_procs`` copies of ``[sys.executable, *argv]`` as one
    jax.distributed job on this host.

    Each copy gets the REPRO_DIST_* env pointing at a shared localhost
    coordinator (process 0).  Blocks until every worker exits and returns
    their `CompletedProcess` results (stdout/stderr captured, text mode);
    the caller asserts on return codes and parses whatever the workers
    printed.
    """
    coord = coordinator or f"127.0.0.1:{free_port()}"
    base = dict(os.environ if env is None else env)
    procs = []
    for pid in range(n_procs):
        e = dict(base)
        e.update({ENV_COORD: coord, ENV_NPROC: str(n_procs),
                  ENV_PID: str(pid), ENV_LOCAL: str(local_devices)})
        procs.append(subprocess.Popen(
            [sys.executable, *argv], env=e, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True))
    out = []
    for pid, p in enumerate(procs):
        try:
            stdout, stderr = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        out.append(subprocess.CompletedProcess(p.args, p.returncode,
                                               stdout, stderr))
    return out
