"""Federated training driver.

Runs the full control plane at example scale on the local devices:
digital twins -> K-means clusters -> (optionally DQN-driven) aggregation
frequency -> trust-weighted mode-A train steps on a reduced architecture.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --steps 50 --clients 4 --smoke

``--smoke`` selects the reduced config (the full assigned configs only
lower on the production mesh via dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.configs import get_config, get_smoke_config
from repro.core import envs
from repro.data import token_stream
from repro.optim import adam
from repro.checkpoint import save_checkpoint


def make_fed_lm_batch(key, cfg, n_clusters, clients, n_micro, bm, seq):
    shape = (n_clusters, clients, n_micro, bm, seq + 1)
    if cfg.num_codebooks > 1:
        shape = shape[:-1] + (cfg.num_codebooks, seq + 1)
    toks = token_stream(key, int(np.prod(shape)), cfg.vocab_size).reshape(shape)
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=0,
                    help="0 = DQN-driven adaptive frequency")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    NC, C = args.clusters, args.clients

    opt = adam(3e-4)
    init = core.build_init_fn(cfg, opt, mode=core.MODE_A, n_clusters=NC,
                              clients_per_cluster=C)
    state = init(key)

    # digital twins of the simulated fleet + trust state
    twins = core.sample_deviation(key, core.init_twins(key, NC * C))
    rep = jnp.ones((NC, C))
    queue = core.init_queue(budget=50.0, horizon=args.steps)

    # DQN agent for adaptive frequency (pretrained quickly on the DT env)
    agent = dcfg = None
    if args.local_steps == 0:
        dcfg = core.DQNConfig(buffer_size=256, batch_size=32)
        agent = core.init_dqn(key, dcfg)

    steps = {}
    for a_i in range(1, 5):
        steps[a_i] = jax.jit(core.build_train_step(
            cfg, opt, mode=core.MODE_A, local_steps=a_i))

    print("step,a_i,loss,queue,seconds")
    for i in range(args.steps):
        key, kb, ka, ke = jax.random.split(key, 4)
        batch = make_fed_lm_batch(kb, cfg, NC, C, 1, args.batch, args.seq)
        if agent is not None:
            obs = jnp.pad(jnp.asarray(
                [float(queue.q), i / args.steps, 0.0]), (0, envs.OBS_DIM - 3))
            a_i = int(core.select_action(ka, agent, dcfg, obs)) % 4 + 1
        else:
            a_i = args.local_steps
        stale = jnp.zeros((NC,))
        t0 = time.time()
        state, metrics = steps[a_i](state, batch, rep, stale)
        loss = float(jnp.mean(metrics["loss"]))
        # energy + queue + trust updates from the DT
        e = float(jnp.mean(core.compute_energy(core.calibrated_freq(twins)))) * a_i
        e += float(jnp.mean(core.comm_energy(
            jnp.zeros(NC * C, jnp.int32), ke)))
        queue = core.step_queue(queue, e)
        div = metrics["divergence"].reshape(-1)
        q = core.learning_quality(div[:, None])
        b = core.belief(twins, q, pkt_fail=0.05)
        rep = core.update_reputation(rep, b.reshape(NC, C), 0.05)
        twins = core.calibrate(twins)
        print(f"{i},{a_i},{loss:.4f},{float(queue.q):.3f},"
              f"{time.time() - t0:.2f}")

    if args.ckpt:
        f = save_checkpoint(args.ckpt, args.steps, state.params)
        print(f"saved,{f}")


if __name__ == "__main__":
    main()
