"""Production meshes for the TPU v5e target.

Single pod: 256 chips as (16, 16) ('data', 'model').
Multi-pod:  2 pods = 512 chips as (2, 16, 16) ('pod', 'data', 'model') —
the 'pod' axis carries the asynchronous FL *clusters* (DESIGN.md §2).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

# v5e hardware constants for the roofline model (benchmarks/roofline.py)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real local devices (CPU smoke / example runs)."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh((data, max(1, min(model, n // data))),
                         ("data", "model"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def n_chips(mesh) -> int:
    return mesh.devices.size
