"""Distributed federated train/serve steps (the paper's technique as a
first-class feature of the training framework).

Two execution modes (DESIGN.md §2):

Mode A — ``fedavg_replica`` (paper-faithful FedAvg):
    params leaves carry leading (NC, C) dims = (clusters, clients/cluster),
    sharded (pod, data).  Local training is vmap-ed over every client;
    intra-cluster aggregation is the trust-weighted average (Eqn 6) over C;
    inter-cluster aggregation is the time-weighted average (Eqn 19) over NC.

Mode B — ``trust_fsdp`` (beyond-paper scale adaptation for 314B/236B):
    params leaves carry a leading (NC,) cluster dim sharded over pod; within a
    cluster, params are FSDP-sharded over data + TP over model.  Trust enters
    as per-example loss weights, making the implicit gradient reduction the
    trust-weighted aggregation (exact for a_i=1 FedSGD).

Every step:  a_i local optimizer steps (DQN-chosen aggregation frequency),
each with grad accumulation over n_micro microbatches, then aggregation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import (ArchConfig, decode_step, init_params, lm_loss,
                      param_specs, weighted_lm_loss)
from ..optim import Optimizer, apply_updates
from .trust import staleness_weights

MODE_A = "fedavg_replica"
MODE_B = "trust_fsdp"


class TrainState(NamedTuple):
    params: Any
    opt: Any
    round: jnp.ndarray          # scalar int32 global round counter


# --------------------------------------------------------------------- #
# aggregation primitives (jnp; lowered to weighted collectives by GSPMD)
# --------------------------------------------------------------------- #
def normalize_weights(rep):
    """(NC, C) raw reputations -> per-cluster normalized trust weights."""
    rep = jnp.maximum(rep, 0.0)
    return rep / (jnp.sum(rep, axis=-1, keepdims=True) + 1e-8)


def intra_cluster_agg(params, w):
    """Eqn 6 over the client dim. leaves (NC, C, ...); w (NC, C)."""
    def agg(x):
        return jnp.einsum("nc...,nc->n...", x, w.astype(x.dtype))
    return jax.tree.map(agg, params)


def inter_cluster_agg(params, staleness):
    """Eqn 19 over the cluster dim. leaves (NC, ...); staleness (NC,)."""
    w = staleness_weights(staleness)
    def agg(x):
        return jnp.einsum("n...,n->...", x, w.astype(x.dtype))
    return jax.tree.map(agg, params)


def client_divergence(params):
    """||w_i - w̄||_2 per client — Eqn 4 learning-quality signal.
    leaves (NC, C, ...) -> (NC, C)."""
    def sq(x):
        mean = jnp.mean(x, axis=1, keepdims=True)
        d = (x - mean).astype(jnp.float32)
        return jnp.sum(d * d, axis=tuple(range(2, x.ndim)))
    total = sum(sq(x) for x in jax.tree.leaves(params))
    return jnp.sqrt(total)


# --------------------------------------------------------------------- #
# local update (shared by both modes; runs under vmap)
# --------------------------------------------------------------------- #
def _local_update(cfg: ArchConfig, opt: Optimizer, loss_fn, local_steps: int,
                  accum_dtype, params, opt_state, batch):
    """a_i local optimizer steps, each accumulating grads over microbatches.
    batch leaves: (n_micro, Bm, ...).  accum_dtype bf16 halves the grad
    buffer for the 30B+ mode-A replicas (DESIGN.md §5)."""

    def one_step(carry, _):
        params, opt_state = carry

        def micro_body(acc, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            loss_acc, g_acc = acc
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(accum_dtype), g_acc, g)
            return (loss_acc + loss, g_acc), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params))
        (loss_sum, g_sum), _ = jax.lax.scan(micro_body, zero, batch)
        n_micro = jax.tree.leaves(batch)[0].shape[0]
        grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32)
                             if g.dtype == jnp.float32 else g / n_micro, g_sum)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), loss_sum / n_micro

    if local_steps == 1:
        # no scan wrapper: a trip-1 while loop double-buffers every
        # params-shaped carry (measured +several GB/chip on grok train)
        (params, opt_state), loss = one_step((params, opt_state), None)
        return params, opt_state, loss
    (params, opt_state), losses = jax.lax.scan(
        one_step, (params, opt_state), None, length=local_steps)
    return params, opt_state, losses[-1]


# --------------------------------------------------------------------- #
# step builders
# --------------------------------------------------------------------- #
def build_train_step(cfg: ArchConfig, opt: Optimizer, *, mode: str,
                     local_steps: int = 1, remat: bool = True,
                     q_chunk: int = 0, accum_dtype=jnp.float32,
                     loss_fn: Callable | None = None) -> Callable:
    """Returns train_step(state, batch, trust_rep, staleness) -> (state, metrics).

    Mode A shapes: params (NC,C,...); batch leaves (NC,C,n_micro,Bm,...);
                   trust_rep (NC,C); staleness (NC,).
    Mode B shapes: params (NC,...);   batch leaves (NC,n_micro,Bm,...) plus
                   batch["weights"] (NC,n_micro,Bm); trust_rep unused there.

    ``loss_fn(params, microbatch) -> scalar`` overrides the default LM loss —
    the paper-repro benchmarks plug the MLP classifier loss in here (the FL
    control plane is model-agnostic; DESIGN.md §4).
    """
    if mode == MODE_A:
        if loss_fn is None:
            def loss_fn(params, mb):
                return lm_loss(params, cfg, mb, remat=remat, q_chunk=q_chunk)

        def train_step(state: TrainState, batch, trust_rep, staleness):
            NC, C = trust_rep.shape
            upd = functools.partial(_local_update, cfg, opt, loss_fn,
                                    local_steps, accum_dtype)
            # vmap over clusters, then clients
            upd = jax.vmap(jax.vmap(upd))
            params, opt_state, losses = upd(state.params, state.opt, batch)
            div = client_divergence(params)
            w = normalize_weights(trust_rep)
            cluster_params = intra_cluster_agg(params, w)          # (NC, ...)
            global_params = inter_cluster_agg(cluster_params, staleness)
            # redistribute: every client of every cluster gets the global model
            new_params = jax.tree.map(
                lambda g, old: jnp.broadcast_to(
                    g[None, None], old.shape).astype(old.dtype),
                global_params, params)
            metrics = {"loss": losses, "divergence": div,
                       "trust_weights": w}
            return TrainState(new_params, opt_state, state.round + 1), metrics

        return train_step

    if mode == MODE_B:
        if loss_fn is None:
            def loss_fn(params, mb):
                return weighted_lm_loss(params, cfg, mb, mb["weights"],
                                        remat=remat, q_chunk=q_chunk)

        def train_step(state: TrainState, batch, trust_rep, staleness):
            upd = functools.partial(_local_update, cfg, opt, loss_fn,
                                    local_steps, accum_dtype)
            upd = jax.vmap(upd)                                     # clusters
            params, opt_state, losses = upd(state.params, state.opt, batch)
            global_params = inter_cluster_agg(params, staleness)
            new_params = jax.tree.map(
                lambda g, old: jnp.broadcast_to(
                    g[None], old.shape).astype(old.dtype),
                global_params, params)
            metrics = {"loss": losses}
            return TrainState(new_params, opt_state, state.round + 1), metrics

        return train_step

    raise ValueError(mode)


def build_serve_step(cfg: ArchConfig) -> Callable:
    """serve_step(params, cache, tokens, step) -> (logits, cache).
    Plain sharded decode (FL is train-time; DESIGN.md §4)."""
    def serve_step(params, cache, tokens, step):
        return decode_step(params, cache, cfg, tokens, step)
    return serve_step


# --------------------------------------------------------------------- #
# state construction + sharding specs
# --------------------------------------------------------------------- #
def build_init_fn(cfg: ArchConfig, opt: Optimizer, *, mode: str,
                  n_clusters: int, clients_per_cluster: int = 0,
                  dtype=jnp.float32) -> Callable:
    """init(key) -> TrainState with FL leading dims broadcast in."""
    lead = ((n_clusters, clients_per_cluster) if mode == MODE_A
            else (n_clusters,))

    def init(key):
        params = init_params(key, cfg, dtype)
        opt_state = opt.init(params)
        bcast = lambda x: jnp.broadcast_to(x, lead + x.shape)
        params = jax.tree.map(bcast, params)
        opt_state = jax.tree.map(bcast, opt_state)
        return TrainState(params, opt_state, jnp.zeros((), jnp.int32))

    return init


def _opt_specs_like(opt_name: str, pspecs, opt_state_shapes):
    """PartitionSpecs for optimizer state, mirroring param specs."""
    if opt_name in ("sgd",):                       # momentum tree or ()
        if not jax.tree.leaves(opt_state_shapes):
            return opt_state_shapes
        return pspecs
    if opt_name in ("adam", "adamw"):
        return {"m": pspecs, "v": pspecs, "t": P()}
    if opt_name == "adafactor":
        def leaf_spec(ps, shapes):
            # shapes: {"r": ..., "c": ...} or {"v": ...}
            if "v" in shapes:
                return {"v": ps}
            parts = list(ps)
            return {"r": P(*parts[:-1]), "c": P(*(parts[:-2] + parts[-1:]))}
        acc = jax.tree.map(leaf_spec, pspecs, opt_state_shapes["acc"],
                           is_leaf=lambda x: isinstance(x, P))
        return {"acc": acc, "t": P()}
    raise ValueError(opt_name)


def train_state_specs(cfg: ArchConfig, state_shapes: TrainState, *,
                      mode: str, opt_name: str, pod_axis=None,
                      tp="model", tp_size=16) -> TrainState:
    """Sharding-spec TrainState matching ``state_shapes`` (from eval_shape)."""
    if mode == MODE_A:
        leading = (pod_axis, "data")
        fsdp, stack_axis = None, None
    else:
        leading = (pod_axis,)
        fsdp = "data" if cfg.shard_scheme in ("ep_tp", "fsdp_tp") else None
        stack_axis = "data" if cfg.shard_scheme == "stack_tp" else None
    pspecs = param_specs(state_shapes.params, cfg, tp=tp, fsdp=fsdp,
                         stack_axis=stack_axis, leading=leading,
                         tp_size=tp_size)
    ospecs = _opt_specs_like(opt_name, pspecs, state_shapes.opt)
    return TrainState(pspecs, ospecs, P())


def batch_specs(cfg: ArchConfig, batch_shapes, *, mode: str, pod_axis=None):
    """Token batches: client dim over data (mode A) / batch dim over data
    (mode B)."""
    def spec(leaf):
        nd = leaf.ndim
        base = [None] * nd
        base[0] = pod_axis
        if mode == MODE_A:
            if nd >= 2:
                base[1] = "data"
        else:
            if nd >= 3:
                base[2] = "data"       # (NC, n_micro, Bm, ...) -> Bm over data
        return P(*base)
    return jax.tree.map(spec, batch_shapes)
