"""MLP autoencoder for federated anomaly detection over IoT telemetry.

The first non-classification workload (FedIoT-style, SNIPPETS.md §3): each
device trains a reconstruction model on its own — mostly normal — telemetry,
clusters aggregate through the same Eqn-6 trust machinery as the
classifiers (learning quality and gradient diversity are loss-agnostic),
and anomalies surface at inference time as samples the global model cannot
reconstruct.  vmap-friendly functional params, same conventions as
`repro.core.mlp`.

Evaluation is threshold-free: `anomaly_auc` ranks reconstruction errors
against the ground-truth anomaly labels (the probability a random anomalous
sample scores above a random normal one), so the metric does not bake in a
contamination-rate assumption.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp_autoencoder(key, dim: int, hidden: int = 64, code: int = 8):
    """dim -> hidden -> code -> hidden -> dim, relu encoder, linear head."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = lambda n: 1.0 / jnp.sqrt(n)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s(dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, code)) * s(hidden),
        "b2": jnp.zeros((code,)),
        "w3": jax.random.normal(k3, (code, hidden)) * s(code),
        "b3": jnp.zeros((hidden,)),
        "w4": jax.random.normal(k4, (hidden, dim)) * s(hidden),
        "b4": jnp.zeros((dim,)),
    }


def encode(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return jax.nn.relu(h @ params["w2"] + params["b2"])


def reconstruct(params, x):
    z = encode(params, x)
    h = jax.nn.relu(z @ params["w3"] + params["b3"])
    return h @ params["w4"] + params["b4"]


def code_mean(params, x):
    """tau(t): mean bottleneck activation — the reconstruction task's stand-in
    for the classifier's hidden-layer mean in the DQN state (§IV-B)."""
    return encode(params, x).mean()


def reconstruction_errors(params, x):
    """Per-sample mean squared reconstruction error, (N,) — the anomaly
    score: normal telemetry lies near the learned manifold, faults do not."""
    r = reconstruct(params, x)
    return jnp.mean((r - x) ** 2, axis=-1)


def reconstruction_loss(params, batch):
    """Mean squared reconstruction error over the batch.  ``batch['y']``
    (the anomaly label) is deliberately unused: training is unsupervised,
    labels exist only for evaluation."""
    return jnp.mean(reconstruction_errors(params, batch["x"]))


def anomaly_auc(scores, labels):
    """Rank AUC of anomaly scores against binary labels (1 = anomalous).

    Mann-Whitney form: (sum of anomaly ranks − n_pos(n_pos+1)/2) /
    (n_pos · n_neg), with midranks for ties.  Returns NaN when either class
    is absent (callers report accuracy as None then).
    """
    scores = jnp.asarray(scores, jnp.float32)
    labels = jnp.asarray(labels)
    pos = (labels > 0).astype(jnp.float32)
    n_pos = jnp.sum(pos)
    n_neg = jnp.sum(1.0 - pos)
    order = jnp.argsort(scores)
    sorted_scores = scores[order]
    base = jnp.arange(1, scores.shape[0] + 1, dtype=jnp.float32)
    # midranks: average the 1-based positions over each tie group
    first = jnp.searchsorted(sorted_scores, sorted_scores, side="left")
    last = jnp.searchsorted(sorted_scores, sorted_scores, side="right")
    mid = 0.5 * (base[first] + base[last - 1])
    ranks = jnp.zeros_like(scores).at[order].set(mid)
    auc = (jnp.sum(ranks * pos) - n_pos * (n_pos + 1.0) / 2.0) / (
        n_pos * n_neg)
    return jnp.where((n_pos > 0) & (n_neg > 0), auc, jnp.nan)
