"""Lyapunov dynamic deficit queue (paper §IV-A, Eqn 12).

Turns the long-term resource budget of P1 into the per-slot drift-plus-penalty
objective P2:

    Q(i+1) = max{ Q(i) + (a_i E_cmp + E_com) - beta R_m / k, 0 }

    P2: argmax_a  v (F(w_{i-1}) - F(w_i)) - Q(i) (a_i E_cmp + E_com)

``v`` grows with the round index (paper: late-stage accuracy is costly, so the
penalty trade-off shifts toward training performance over time).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class DeficitQueue(NamedTuple):
    q: jnp.ndarray              # scalar (or per-cluster) backlog
    budget: float               # beta * R_m: total resource budget
    horizon: int                # k: planned number of aggregations

    @property
    def per_slot(self):
        return self.budget / self.horizon


def init_queue(budget: float, horizon: int, shape=()) -> DeficitQueue:
    return DeficitQueue(q=jnp.zeros(shape, jnp.float32),
                        budget=float(budget), horizon=int(horizon))


def queue_advance(q, consumed, per_slot):
    """Eqn 12 on bare arrays — the jit/scan-friendly form.

    ``q`` is the backlog leaf (scalar or per-cluster), ``consumed`` the
    realized slot cost a_i·E_cmp + E_com, ``per_slot`` the replenishment
    beta·R_m/k.  An infinite ``per_slot`` (no budget) pins the queue at 0.
    """
    return jnp.maximum(q + consumed - per_slot, 0.0)


def step_queue(queue: DeficitQueue, consumed) -> DeficitQueue:
    """Eqn 12. ``consumed`` = a_i * E_cmp + E_com for the slot."""
    return queue._replace(q=queue_advance(queue.q, consumed, queue.per_slot))


def drift_penalty_reward(loss_prev, loss_cur, consumed, queue: DeficitQueue,
                         v: float) -> jnp.ndarray:
    """Eqn 15: R = v (F(w_{i-1}) - F(w_i)) - Q(i) (a_i E_cmp + E_com)."""
    return v * (loss_prev - loss_cur) - queue.q * consumed


def v_schedule(round_idx, v0: float = 1.0, growth: float = 0.01):
    """v increases with training rounds (paper §IV-A)."""
    return v0 * (1.0 + growth * round_idx)
