"""The paper's device-scale model: a small MLP classifier (MNIST-shaped).

Used by the paper-repro benchmarks (Figs 3, 6-8) and the real-environment
validation of the DQN agent.  vmap-friendly functional params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp_classifier(key, dim=784, hidden=200, n_classes=10):
    k1, k2 = jax.random.split(key)
    s = lambda n: 1.0 / jnp.sqrt(n)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s(dim),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, n_classes)) * s(hidden),
        "b2": jnp.zeros((n_classes,)),
    }


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_hidden_mean(params, x):
    """tau(t): mean hidden-layer activation — part of the DQN state (§IV-B)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h.mean()


def classifier_loss(params, batch):
    logits = mlp_logits(params, batch["x"])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_logits(params, x), -1) == y)
