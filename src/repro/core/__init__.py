"""Core — the paper's contribution as composable JAX modules.

twin        digital twins of the device fleet (Eqns 1-2)
trust       subjective-logic trust & weighted aggregation (Eqns 4-6, 19)
energy      compute/communication energy + Markov channel (Eqns 7-8)
lyapunov    dynamic deficit queue & drift-plus-penalty (Eqns 12-15)
dqn         adaptive aggregation-frequency agent (Alg. 1, Eqns 16-18)
envs        DT-simulated FL environment the agent trains in (§IV-C)
clustering  K-means device clustering + tolerance bound (Alg. 2)
async_fl    legacy shims over the repro.api engine (§IV-D orchestrator)
fl_step     distributed train/serve steps for the assigned architectures
mlp         the paper's device-scale classifier
"""
from .twin import TwinState, init_twins, sample_deviation, calibrate, \
    calibrated_freq, observe_round
from .trust import (belief, gradient_diversity, learning_quality,
                    staleness_weights, time_weighted_average,
                    trust_weighted_average, trust_weights,
                    update_reputation)
from .energy import ChannelParams, compute_energy, comm_energy, \
    channel_transition, step_channel
from .lyapunov import DeficitQueue, init_queue, step_queue, \
    drift_penalty_reward, v_schedule
from .dqn import DQNConfig, DQNState, init_dqn, select_action, store, \
    train_step as dqn_train_step, q_values, epsilon
from .clustering import kmeans, cluster_devices, tolerance_bound
from .fl_step import (MODE_A, MODE_B, TrainState, build_train_step,
                      build_serve_step, build_init_fn, train_state_specs,
                      batch_specs, normalize_weights, intra_cluster_agg,
                      inter_cluster_agg, client_divergence)
from .async_fl import AsyncFLConfig, AsyncFederation, FLTrace, \
    run_sync_baseline
from .mlp import init_mlp_classifier, mlp_logits, classifier_loss, accuracy
from .robust import (krum, multi_krum, coordinate_median, trimmed_mean,
                     AGGREGATORS)
from .privacy import clip_update, dp_aggregate, add_gaussian_noise
