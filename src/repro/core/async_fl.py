"""Clustering-based asynchronous federated learning (paper §IV-D, Alg. 2).

Host-side discrete-event orchestrator over the jit-ed FL steps:

  Step 1  K-means clustering of devices by (data size, compute power);
  Step 2  per-cluster aggregation frequency a_i from the trained DQN, capped
          by the tolerance bound a_i f_i <= alpha T_m (Alg. 2 lines 4-6);
  Step 3  intra-cluster trust-weighted aggregation (Eqn 6);
  Step 4  inter-cluster time-weighted aggregation (Eqn 19).

Wall-clock is *simulated*: a cluster's round takes a_i / f_min(cluster)
simulated seconds (its straggler), so clusters aggregate asynchronously —
exactly the straggler-elimination mechanism of the paper.  The synchronous
fixed-frequency baseline (`run_sync_baseline`) is the benchmark scheme.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import dqn as dqn_lib
from .clustering import cluster_devices, tolerance_bound
from .energy import (ChannelParams, channel_transition, comm_energy,
                     compute_energy, step_channel)
from .mlp import accuracy, classifier_loss, init_mlp_classifier, mlp_hidden_mean
from .trust import (belief, gradient_diversity, learning_quality,
                    trust_weights, trust_weighted_average, update_reputation)
from .twin import (TwinState, calibrate, calibrated_freq, init_twins,
                   observe_round, sample_deviation)


@dataclasses.dataclass
class AsyncFLConfig:
    n_devices: int = 16
    n_clusters: int = 4
    local_batch: int = 64
    sim_seconds: float = 60.0        # simulated wall-clock budget
    alpha0: float = 0.5              # tolerance factor (grows with rounds)
    alpha_growth: float = 0.02
    iota: float = 0.1                # Eqn 5 uncertainty coefficient
    pkt_fail: float = 0.05
    p_good: float = 0.5
    malicious_frac: float = 0.0
    dt_max_dev: float = 0.2
    calibrate_dt: bool = True
    lr: float = 0.1
    seed: int = 0
    fixed_frequency: Optional[int] = None   # not None => benchmark scheme
    aggregator: str = "trust"   # trust | fedavg | krum | multi_krum |
                                # median | trimmed_mean (robust baselines)
    dp_clip: float = 0.0        # >0: client-level DP clipping norm
    dp_noise: float = 0.0       # DP noise multiplier


@dataclasses.dataclass
class FLTrace:
    times: List[float]
    accs: List[float]
    losses: List[float]
    energies: List[float]
    agg_counts: List[int]


def _client_sgd(params, batch, lr, steps):
    def one(_, p):
        g = jax.grad(classifier_loss)(p, batch)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)
    return jax.lax.fori_loop(0, steps, one, params)


_client_sgd_v = jax.jit(jax.vmap(_client_sgd, in_axes=(0, 0, None, None)),
                        static_argnums=3)


def _flatten_params(tree):
    return jnp.concatenate([x.reshape(x.shape[0], -1)
                            for x in jax.tree.leaves(tree)], axis=1)


class AsyncFederation:
    """Discrete-event asynchronous clustered FL on the paper's device-scale
    task.  ``agent`` (trained DQN) picks per-cluster frequencies; pass
    ``cfg.fixed_frequency`` for the benchmark scheme instead."""

    def __init__(self, cfg: AsyncFLConfig, data, parts,
                 agent: Optional[dqn_lib.DQNState] = None,
                 dqn_cfg: Optional[dqn_lib.DQNConfig] = None):
        self.cfg = cfg
        self.data = data
        self.parts = parts
        self.agent = agent
        self.dqn_cfg = dqn_cfg or dqn_lib.DQNConfig()
        key = jax.random.PRNGKey(cfg.seed)
        (self.key, kt, kd, kc, kp, km) = jax.random.split(key, 6)
        self.twins = sample_deviation(
            kd, init_twins(kt, cfg.n_devices), cfg.dt_max_dev)
        sizes = jnp.asarray([len(p) for p in parts], jnp.float32)
        self.twins = self.twins._replace(data_size=sizes)
        self.assign, _ = cluster_devices(kc, self.twins, cfg.n_clusters)
        self.assign = np.asarray(self.assign)
        self.global_params = init_mlp_classifier(kp, dim=data.x.shape[1])
        self.cluster_params = [self.global_params] * cfg.n_clusters
        self.cluster_ts = np.zeros(cfg.n_clusters)      # timestamps (rounds)
        self.round = 0
        self.rep = jnp.ones((cfg.n_devices,))
        self.channel = jnp.zeros((cfg.n_devices,), jnp.int32)
        self.malicious = np.zeros(cfg.n_devices, bool)
        n_mal = int(cfg.malicious_frac * cfg.n_devices)
        if n_mal:
            self.malicious[np.asarray(
                jax.random.choice(km, cfg.n_devices, (n_mal,), replace=False))] = True
        self.energy_used = 0.0
        self.agg_count = 0

    # ---------------------------------------------------------------- #
    def _cluster_freq(self, c: int) -> float:
        members = np.where(self.assign == c)[0]
        f = np.asarray(calibrated_freq(self.twins))[members]
        return float(f.min()) if len(members) else 1.0

    def _pick_frequency(self, c: int, obs) -> int:
        if self.cfg.fixed_frequency is not None:
            a = self.cfg.fixed_frequency
        elif self.agent is not None:
            q = dqn_lib.q_values(self.agent.eval_params, obs)
            a = int(jnp.argmax(q)) + 1
        else:
            a = 5
        # Alg. 2 tolerance bound
        t_min = min(1.0 / max(self._cluster_freq(cc), 1e-6)
                    for cc in range(self.cfg.n_clusters))
        alpha = min(1.0, self.cfg.alpha0 +
                    self.cfg.alpha_growth * self.round)
        a = int(tolerance_bound(jnp.asarray(a), jnp.asarray(
            self._cluster_freq(c)), jnp.asarray(t_min), alpha))
        return max(1, min(a, self.dqn_cfg.n_actions))

    def _obs(self, c: int) -> jnp.ndarray:
        from .envs import OBS_DIM
        members = self.assign == c
        loss = float(np.nan_to_num(np.asarray(self.twins.loss)[members].mean(),
                                   posinf=2.3))
        tau = float(mlp_hidden_mean(self.cluster_params[c],
                                    self.data.x[:256]))
        ch = np.asarray(jax.nn.one_hot(self.channel, 3).mean(0))
        feats = np.concatenate([
            [loss, 2.3 - loss, self.energy_used, self.round / 100.0, tau],
            np.eye(10)[min(9, self.agg_count % 10)], ch,
            [float(calibrated_freq(self.twins)[members].mean()), 0.0, 0.0]])
        return jnp.asarray(np.pad(feats, (0, OBS_DIM - len(feats))),
                           jnp.float32)

    # ---------------------------------------------------------------- #
    def _cluster_round(self, c: int, a: int, kround):
        """One asynchronous cluster round: local training on every member,
        trust-weighted intra-cluster aggregation.  Returns sim duration."""
        cfg = self.cfg
        members = np.where(self.assign == c)[0]
        kb, ke, kc2 = jax.random.split(kround, 3)

        # --- local batches (possibly label-flipped for malicious nodes)
        xs, ys = [], []
        for m in members:
            ix = self.parts[m]
            sel = np.asarray(jax.random.choice(
                jax.random.fold_in(kb, int(m)), jnp.asarray(ix),
                (cfg.local_batch,), replace=len(ix) < cfg.local_batch))
            y = np.asarray(self.data.y)[sel]
            if self.malicious[m]:
                y = (y + 1) % 10                       # Byzantine label flip
            xs.append(np.asarray(self.data.x)[sel])
            ys.append(y)
        batch = {"x": jnp.asarray(np.stack(xs)),
                 "y": jnp.asarray(np.stack(ys))}

        # --- a local steps on every member (vmap), from the cluster model
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(members),) + x.shape),
            self.cluster_params[c])
        new = _client_sgd_v(stacked, batch, cfg.lr, a)

        # --- trust update (Eqns 4-5) & weighted aggregation (Eqn 6)
        upd_flat = _flatten_params(new) - _flatten_params(stacked)
        q = learning_quality(upd_flat)
        div = gradient_diversity(upd_flat)
        tw_m = jax.tree.map(lambda x: x[members], self.twins._asdict())
        twins_m = TwinState(**tw_m)
        b = belief(twins_m, q, self.cfg.pkt_fail, div)
        rep_m = update_reputation(self.rep[members], b, cfg.pkt_fail, cfg.iota)
        self.rep = self.rep.at[jnp.asarray(members)].set(rep_m)
        w = trust_weights(rep_m)
        if cfg.aggregator == "trust":
            agg = trust_weighted_average(new, w)
        elif cfg.aggregator == "fedavg":
            agg = trust_weighted_average(
                new, jnp.full_like(w, 1.0 / len(members)))
        else:
            from .robust import AGGREGATORS
            agg = AGGREGATORS[cfg.aggregator](new)
        if cfg.dp_clip > 0.0:
            from .privacy import dp_aggregate
            self.key, kdp = jax.random.split(self.key)
            uniform = jnp.full((len(members),), 1.0 / len(members))
            agg = dp_aggregate(
                kdp, new, self.cluster_params[c],
                w if cfg.aggregator == "trust" else uniform,
                cfg.dp_clip, cfg.dp_noise)
        self.cluster_params[c] = agg

        # --- losses, energy, twins
        losses = jax.vmap(classifier_loss, in_axes=(0, 0))(new, batch)
        e_cmp = a * compute_energy(
            (self.twins.freq + self.twins.freq_dev)[members])
        e_com = comm_energy(self.channel[members], ke)
        self.energy_used += float(e_cmp.sum() + e_com.sum())
        full_loss = self.twins.loss.at[jnp.asarray(members)].set(losses)
        full_e = jnp.zeros_like(self.twins.energy).at[
            jnp.asarray(members)].set(e_cmp + e_com)
        self.twins = observe_round(
            self.twins, full_loss, full_e,
            jnp.asarray(self.malicious, jnp.float32))
        if cfg.calibrate_dt:
            self.twins = calibrate(self.twins)
        self.channel = step_channel(kc2, self.channel,
                                    channel_transition(cfg.p_good))
        return float(a) / max(self._cluster_freq(c), 1e-6)

    def _global_aggregate(self):
        """Eqn 19: time-weighted aggregation across clusters."""
        staleness = jnp.asarray(self.round - self.cluster_ts, jnp.float32)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *self.cluster_params)
        w = (jnp.e / 2.0) ** (-staleness)
        w = w / w.sum()
        self.global_params = trust_weighted_average(stacked, w)
        self.agg_count += 1

    # ---------------------------------------------------------------- #
    def run(self, eval_every: float = 1.0) -> FLTrace:
        cfg = self.cfg
        trace = FLTrace([], [], [], [], [])
        events = [(0.0, c) for c in range(cfg.n_clusters)]
        heapq.heapify(events)
        t = 0.0
        next_eval = 0.0
        while events and t < cfg.sim_seconds:
            t, c = heapq.heappop(events)
            if t >= cfg.sim_seconds:
                break
            self.key, ka, kr = jax.random.split(self.key, 3)
            a = self._pick_frequency(c, self._obs(c))
            dur = self._cluster_round(c, a, kr)
            self.round += 1
            self.cluster_ts[c] = self.round
            self._global_aggregate()
            # redistribute global model to the cluster (async pull)
            self.cluster_params[c] = self.global_params
            heapq.heappush(events, (t + dur, c))
            if t >= next_eval:
                acc = float(accuracy(self.global_params,
                                     self.data.x, self.data.y))
                loss = float(classifier_loss(
                    self.global_params,
                    {"x": self.data.x[:1024], "y": self.data.y[:1024]}))
                trace.times.append(t)
                trace.accs.append(acc)
                trace.losses.append(loss)
                trace.energies.append(self.energy_used)
                trace.agg_counts.append(self.agg_count)
                next_eval = t + eval_every
        return trace


def run_sync_baseline(cfg: AsyncFLConfig, data, parts) -> FLTrace:
    """Benchmark scheme: synchronous FedAvg at a fixed frequency — one
    cluster, fixed a, every round gated on the slowest device."""
    sync = dataclasses.replace(cfg, n_clusters=1,
                               fixed_frequency=cfg.fixed_frequency or 5)
    return AsyncFederation(sync, data, parts).run()
