"""Deprecation shims for the pre-`repro.api` entry points.

The discrete-event orchestrator that used to live here (paper §IV-D, Alg. 2)
is now `repro.api.engine.DeviceScaleEngine`, with its policy choices
(aggregation rule, frequency controller, task, privacy) pluggable through
the `repro.api` registries.  `AsyncFederation` and `run_sync_baseline`
remain as thin wrappers that translate the legacy `AsyncFLConfig` into a
`FederationSpec` and delegate, so both entry points produce identical
traces at the same seed (tests/test_api.py proves the translation is
faithful).  New code should use:

    from repro.api import Federation, FederationSpec
    trace = Federation.from_spec(FederationSpec(...)).run()
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from . import dqn as dqn_lib


@dataclasses.dataclass
class AsyncFLConfig:
    n_devices: int = 16
    n_clusters: int = 4
    local_batch: int = 64
    sim_seconds: float = 60.0        # simulated wall-clock budget
    alpha0: float = 0.5              # tolerance factor (grows with rounds)
    alpha_growth: float = 0.02
    iota: float = 0.1                # Eqn 5 uncertainty coefficient
    pkt_fail: float = 0.05
    p_good: float = 0.5
    malicious_frac: float = 0.0
    dt_max_dev: float = 0.2
    calibrate_dt: bool = True
    lr: float = 0.1
    seed: int = 0
    fixed_frequency: Optional[int] = None   # not None => benchmark scheme
    aggregator: str = "trust"   # trust | fedavg | krum | multi_krum |
                                # median | trimmed_mean (robust baselines)
    dp_clip: float = 0.0        # >0: client-level DP clipping norm
    dp_noise: float = 0.0       # DP noise multiplier


@dataclasses.dataclass
class FLTrace:
    """Legacy list-style trace (see repro.api.records for the new schema)."""
    times: List[float]
    accs: List[float]
    losses: List[float]
    energies: List[float]
    agg_counts: List[int]


class AsyncFederation:
    """Deprecated: use ``repro.api.Federation``.  Thin wrapper over
    `DeviceScaleEngine`; ``agent`` (trained DQN) picks per-cluster
    frequencies, ``cfg.fixed_frequency`` selects the benchmark scheme."""

    def __init__(self, cfg: AsyncFLConfig, data, parts,
                 agent: Optional[dqn_lib.DQNState] = None,
                 dqn_cfg: Optional[dqn_lib.DQNConfig] = None):
        from repro.api import Federation, legacy_spec
        from repro.api.components import DQNController, FixedController
        self.cfg = cfg
        self.dqn_cfg = dqn_cfg or dqn_lib.DQNConfig()
        spec = legacy_spec(cfg)
        if cfg.fixed_frequency is not None:
            controller = FixedController(cfg.fixed_frequency,
                                         n_actions=self.dqn_cfg.n_actions)
        elif agent is not None:
            controller = DQNController(agent, self.dqn_cfg)
        else:
            controller = FixedController(5, n_actions=self.dqn_cfg.n_actions)
        self.agent = agent
        self._fed = Federation.from_spec(spec, data=data, parts=parts,
                                         controller=controller)

    def run(self, eval_every: float = 1.0) -> FLTrace:
        trace = self._fed.run(eval_every=eval_every)
        return FLTrace(times=trace.times, accs=trace.accs,
                       losses=trace.losses, energies=trace.energies,
                       agg_counts=trace.agg_counts)

    def __getattr__(self, name):
        if name == "_fed":                   # not yet set: avoid recursion
            raise AttributeError(name)
        return getattr(self._fed.engine, name)


def run_sync_baseline(cfg: AsyncFLConfig, data, parts) -> FLTrace:
    """Benchmark scheme: synchronous FedAvg at a fixed frequency — one
    cluster, fixed a, every round gated on the slowest device."""
    sync = dataclasses.replace(cfg, n_clusters=1,
                               fixed_frequency=cfg.fixed_frequency or 5)
    return AsyncFederation(sync, data, parts).run()
