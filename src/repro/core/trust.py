"""Trust-based aggregation (paper §III-C, Eqns 4-6).

Belief of curator j in node i at slot t (Eqn 4):

    b_{i->j}^t = (1 - u) * q / f̂_i  *  alpha / (alpha + beta)

with u the packet-failure probability, q the learning quality (distance of the
node's update from the honest majority, FoolsGold-style), f̂ the DT mapping
deviation, and (alpha, beta) the positive/malicious interaction counts.

Reputation (Eqn 5):  T_{i->j} = sum_t b^t + iota * u
Aggregation (Eqn 6): w_k = sum_i T_i w_i / sum_i T_i

All functions are jnp-pure; `trust_weighted_average` is the jnp oracle whose
TPU hot path is kernels/trust_aggregate.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .twin import TwinState

_EPS = 1e-8


def learning_quality(updates_flat: jnp.ndarray, mask=None) -> jnp.ndarray:
    """q_{i->j} from Eqn 4: normalized distance of each client's update from
    the mean update (honesty-of-the-majority assumption).  FoolsGold-style:
    *small* distance from the majority direction => high quality; extreme
    outliers (malicious / lazy) => low quality.

    updates_flat: (n, P) flattened per-client parameter updates.
    mask: optional (n,) validity mask — padded rows (fused fixed-shape
    rounds) are excluded from the majority statistics; their own scores are
    arbitrary and must be masked by the caller.
    -> (n,) quality scores in (0, 1].
    """
    if mask is None:
        mean = jnp.mean(updates_flat, axis=0, keepdims=True)
        dist = jnp.linalg.norm(updates_flat - mean, axis=1)       # (n,)
        rel = dist / (jnp.sum(dist) + _EPS)                       # Eqn 4's ratio
        n = updates_flat.shape[0]
        # convert distance-share to quality: majority-consistent -> ~1
        return jnp.clip(1.0 - rel * n / jnp.maximum(n - 1, 1), _EPS, 1.0)
    m = mask.astype(updates_flat.dtype)
    cnt = jnp.maximum(jnp.sum(m), 1.0)
    mean = jnp.sum(updates_flat * m[:, None], axis=0,
                   keepdims=True) / cnt
    dist = jnp.linalg.norm(updates_flat - mean, axis=1) * m
    rel = dist / (jnp.sum(dist) + _EPS)
    # parenthesized so the count ratio is one value whether `cnt` is a
    # compile-time constant (standalone engines close over their mask) or a
    # runtime operand (the population engine vmaps over stacked masks):
    # XLA folds `rel * cnt / d` into `rel * (cnt/d)` only in the constant
    # world, which costs a ulp of bit-parity between the two
    return jnp.clip(1.0 - rel * (cnt / jnp.maximum(cnt - 1.0, 1.0)),
                    _EPS, 1.0)


def gradient_diversity(updates_flat: jnp.ndarray, mask=None) -> jnp.ndarray:
    """FoolsGold signal [12]: max pairwise cosine similarity per client.
    Sybil-coordinated clients share gradient direction (cs -> 1) and are
    down-weighted.  ``mask`` excludes padded rows from the pairwise max."""
    norm = updates_flat / (jnp.linalg.norm(updates_flat, axis=1, keepdims=True) + _EPS)
    cs = norm @ norm.T
    cs = cs - jnp.eye(cs.shape[0]) * 2.0       # exclude self
    if mask is not None:
        cs = jnp.where(mask[None, :], cs, -2.0)   # padded peers never count
    mx = jnp.max(cs, axis=1)
    return jnp.clip(1.0 - jnp.maximum(mx, 0.0), _EPS, 1.0)


def belief(twins: TwinState, quality, pkt_fail, diversity=None) -> jnp.ndarray:
    """Eqn 4 with the DT deviation in the denominator (deviation-normalized
    belief) and the subjective-logic interaction ratio.

    The deviation term is 1/(1 + f̂): monotonically down-weighting badly
    mapped twins while keeping b <= quality * inter.  (A raw 1/f̂ amplifies
    belief ~1000x for whichever device's twin happens to calibrate best,
    swamping the honesty signals — found by the Byzantine seed test.)"""
    fdev = jnp.abs(twins.freq_dev - twins.dev_estimate)
    inter = twins.alpha / (twins.alpha + twins.beta + _EPS)
    b = (1.0 - pkt_fail) * quality / (1.0 + fdev) * inter
    if diversity is not None:
        # bounded FoolsGold factor (1+d)/2 in [1/2, 1]: coordinated sybils
        # (d -> 0) still lose half their belief, but a well-aligned honest
        # fleet (near-IID reconstruction gradients, d at the eps clip) no
        # longer hands a divergent-direction attacker (d -> 1) an
        # unbounded multiplicative advantage — found by the fault-injection
        # bench, where raw-d trust *collapsed* under input poisoning
        b = b * 0.5 * (1.0 + diversity)
    return b


def update_reputation(rep, b, pkt_fail, iota: float = 0.1) -> jnp.ndarray:
    """Eqn 5 (running form): accumulate belief + uncertainty term."""
    return rep + b + iota * pkt_fail


def trust_weights(rep, mask=None) -> jnp.ndarray:
    """Normalized aggregation weights: T_i / sum T (Eqn 6 numerator shares).
    Degenerate fleet (all reputations <= 0) falls back to uniform weights —
    found by the hypothesis simplex property test.  With ``mask``, padded
    clients get exactly-zero weight and the uniform fallback spreads over
    the valid clients only."""
    rep = jnp.maximum(rep, 0.0)
    if mask is None:
        total = jnp.sum(rep)
        n = rep.shape[-1] if rep.ndim else 1
        uniform = jnp.full_like(rep, 1.0 / max(n, 1))
        return jnp.where(total > 1e-6, rep / jnp.maximum(total, 1e-6), uniform)
    m = mask.astype(rep.dtype)
    rep = rep * m
    total = jnp.sum(rep)
    uniform = m / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.where(total > 1e-6, rep / jnp.maximum(total, 1e-6), uniform)


def trust_weighted_average(client_params, weights):
    """Eqn 6: weighted average over the leading client dim of a pytree.

    client_params: pytree with leaves (n, ...); weights: (n,) summing to 1.
    jnp oracle for kernels/trust_aggregate.py.
    """
    def wavg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * w, axis=0)
    return jax.tree.map(wavg, client_params)


def staleness_weights(staleness, base: float = jnp.e / 2) -> jnp.ndarray:
    """Eqn 19's normalized time-decay weights (e/2)^{-(t - timestamp_j)}.

    The single implementation shared by every Eqn-19 call site
    (`time_weighted_average`, `fl_step.inter_cluster_agg`, the
    `repro.api` engine's global aggregate).

    staleness: (n_clusters,) = t - timestamp_j  (rounds since last update)
    -> (n_clusters,) weights summing to 1.
    """
    w = base ** (-staleness.astype(jnp.float32))
    return w / (jnp.sum(w) + _EPS)


def time_weighted_average(cluster_params, staleness, base: float = jnp.e / 2):
    """Eqn 19: inter-cluster aggregation with exponential time decay.

    cluster_params: pytree with leaves (n_clusters, ...)
    staleness: (n_clusters,) = t - timestamp_j  (rounds since last update)
    """
    w = staleness_weights(staleness, base)
    return trust_weighted_average(cluster_params, w), w
