"""DT-simulated federated-learning environment for DQN training (paper §IV).

The paper's key systems claim: *the DRL agent interacts with the digital
twins, not the physical devices* — "through DTs, the agent achieves the same
training effect as the real environment at a lower cost" (§IV-C).  This module
is that surrogate: a jit-able MDP whose dynamics come from the DT state
(loss-decay curve with non-linear aggregation gain, Eqn-7/8 energy, Markov
channel), used to train the frequency agent before deployment.  The *real*
environment (actual federated training) lives in async_fl.py and is used by
the benchmarks to validate the agent end-to-end.

Observation layout (state_dim=48, matching the paper's 48 x 200 x 10 net):
    [ loss, dloss, queue, round_frac, budget_frac,
      onehot(last_action, 10), channel_fracs(3), mean_freq, mean_dev,
      tau (mean hidden activation proxy), pad... ]
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .energy import (ChannelParams, channel_transition, comm_energy,
                     compute_energy, step_channel)
from .lyapunov import v_schedule
from .twin import TwinState, calibrated_freq, init_twins, sample_deviation

OBS_DIM = 48
N_ACTIONS = 10


class EnvParams(NamedTuple):
    n_devices: int = 16
    horizon: int = 100              # k: planned aggregation rounds
    budget: float = 250.0           # beta * R_m (E_com ~ E_cmp regime)
    p_good: float = 0.5             # stationary good-channel probability
    kappa: float = 0.08             # loss-decay rate per local step
    f_star: float = 0.1             # asymptotic loss
    f0: float = 2.3                 # initial loss (ln 10)
    v0: float = 1.0
    v_growth: float = 0.02
    noise: float = 0.01
    reward_scale: float = 0.02      # keeps Q-values O(1) for stable TD
    calibrate_dt: bool = True       # False => Fig-3 "with DT deviation" arm
    channel: ChannelParams = ChannelParams()


class EnvState(NamedTuple):
    twins: TwinState
    loss: jnp.ndarray               # scalar global loss F(w)
    queue: jnp.ndarray              # scalar deficit queue Q(i)
    spent: jnp.ndarray              # cumulative resource use
    round: jnp.ndarray              # int32
    channel: jnp.ndarray            # (n,) int32 per-device channel state
    last_action: jnp.ndarray        # int32
    key: jnp.ndarray


def _obs(p: EnvParams, s: EnvState) -> jnp.ndarray:
    ch = jax.nn.one_hot(s.channel, 3).mean(0)
    feats = jnp.concatenate([
        jnp.array([s.loss, p.f0 - s.loss, s.queue,
                   s.round / p.horizon, s.spent / p.budget]),
        jax.nn.one_hot(s.last_action, N_ACTIONS),
        ch,
        jnp.array([calibrated_freq(s.twins).mean(),
                   jnp.abs(s.twins.freq_dev - s.twins.dev_estimate).mean(),
                   jnp.tanh(s.loss)]),   # tau: mean-activation proxy
    ])
    return jnp.pad(feats, (0, OBS_DIM - feats.shape[0]))


def reset(key, p: EnvParams):
    kt, kd, kc, ks = jax.random.split(key, 4)
    twins = sample_deviation(kd, init_twins(kt, p.n_devices))
    channel = step_channel(
        kc, jnp.zeros((p.n_devices,), jnp.int32), channel_transition(p.p_good))
    s = EnvState(twins=twins, loss=jnp.asarray(p.f0),
                 queue=jnp.zeros(()), spent=jnp.zeros(()),
                 round=jnp.zeros((), jnp.int32), channel=channel,
                 last_action=jnp.zeros((), jnp.int32), key=ks)
    return s, _obs(p, s)


def step(s: EnvState, action, p: EnvParams):
    """action in [0, N_ACTIONS): a_i = action + 1 local steps this round.
    Returns (state', obs, reward, done, info)."""
    a = action.astype(jnp.float32) + 1.0
    key, kc, kn, ke = jax.random.split(s.key, 4)

    # --- energy (Eqn 7/8); DT deviation biases the *estimated* compute term
    freq_true = s.twins.freq + s.twins.freq_dev
    freq_est = calibrated_freq(s.twins) if p.calibrate_dt else s.twins.freq
    e_cmp = compute_energy(freq_true, p.channel).mean()
    e_cmp_est = compute_energy(freq_est, p.channel).mean()
    e_com = comm_energy(s.channel, ke, p.channel).mean()
    consumed = a * e_cmp + e_com
    estimated = a * e_cmp_est + e_com

    # --- loss decay with non-linear (diminishing) aggregation gain
    decay = jnp.exp(-p.kappa * a / (1.0 + 0.05 * s.round.astype(jnp.float32)))
    mis_est = jnp.abs(e_cmp_est - e_cmp) / jnp.maximum(e_cmp, 1e-6)
    noise = p.noise * jax.random.normal(kn, ()) * (1.0 + 5.0 * mis_est)
    new_loss = jnp.maximum(
        p.f_star + (s.loss - p.f_star) * decay + noise, 0.0)

    # --- Lyapunov deficit queue (Eqn 12)
    per_slot = p.budget / p.horizon
    queue = jnp.maximum(s.queue + consumed - per_slot, 0.0)

    # --- reward (Eqn 15) using the DT-*estimated* cost
    v = v_schedule(s.round, p.v0, p.v_growth)
    reward = (v * (s.loss - new_loss) - s.queue * estimated) * p.reward_scale

    channel = step_channel(kc, s.channel, channel_transition(p.p_good))
    twins = s.twins._replace(loss=jnp.full_like(s.twins.loss, new_loss))
    if p.calibrate_dt:
        from .twin import calibrate
        twins = calibrate(twins)
    ns = EnvState(twins=twins, loss=new_loss, queue=queue,
                  spent=s.spent + consumed, round=s.round + 1,
                  channel=channel, last_action=action.astype(jnp.int32),
                  key=key)
    done = (ns.round >= p.horizon) | (ns.spent >= p.budget)
    info = {"consumed": consumed, "e_com": e_com, "e_cmp": e_cmp,
            "queue": queue, "good_frac": (s.channel == 0).mean()}
    return ns, _obs(p, ns), reward, done, info
