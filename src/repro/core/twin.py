"""Digital twins of Industrial-IoT training devices (paper §III-A).

``DT_i(t) = {F(w_i^t), f_i(t), E_i(t)}``  (Eqn 1) — the twin mirrors each
device's training state (loss), compute capability (CPU/accelerator frequency)
and energy consumption.  The mapping has a deviation ``f̂_i(t)`` (Eqn 2);
calibration subtracts a running empirical estimate of that deviation.

Everything is a JAX-friendly struct-of-arrays over the device fleet so the
control plane (trust weights, DQN state) is computed with jnp ops and can be
jit'ed alongside the training step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TwinState(NamedTuple):
    """Struct-of-arrays digital twin of an n-device fleet."""
    loss: jnp.ndarray          # (n,)  F(w_i^t): per-client training loss
    freq: jnp.ndarray          # (n,)  mapped compute capability f_i(t) [GHz]
    freq_dev: jnp.ndarray      # (n,)  current mapping deviation f̂_i(t)
    dev_estimate: jnp.ndarray  # (n,)  running empirical deviation estimate
    energy: jnp.ndarray        # (n,)  cumulative energy E_i(t) [J]
    data_size: jnp.ndarray     # (n,)  |D_i| local dataset sizes
    alpha: jnp.ndarray         # (n,)  positive-interaction counts (Eqn 4)
    beta: jnp.ndarray          # (n,)  malicious/lazy-update counts (Eqn 4)
    router_entropy: jnp.ndarray  # (n,) MoE learning-quality extension


def init_twins(key, n: int, *, freq_lo=0.5, freq_hi=2.0,
               data_lo=256, data_hi=4096) -> TwinState:
    kf, kd = jax.random.split(key)
    freq = jax.random.uniform(kf, (n,), minval=freq_lo, maxval=freq_hi)
    data = jax.random.randint(kd, (n,), data_lo, data_hi).astype(jnp.float32)
    z = jnp.zeros((n,), jnp.float32)
    return TwinState(loss=jnp.full((n,), jnp.inf), freq=freq,
                     freq_dev=z, dev_estimate=z, energy=z, data_size=data,
                     alpha=jnp.ones((n,)), beta=z, router_entropy=z)


def sample_deviation(key, twins: TwinState, max_dev: float = 0.2) -> TwinState:
    """Paper §V: DT mapping error ~ U(0, 0.2) of the true frequency."""
    dev = jax.random.uniform(key, twins.freq.shape, minval=0.0, maxval=max_dev)
    return twins._replace(freq_dev=dev * twins.freq)


def calibrate(twins: TwinState, ema: float = 0.9) -> TwinState:
    """Self-calibration (Eqn 2): fold the observed deviation into a running
    estimate; calibrated frequency = mapped + estimate."""
    est = ema * twins.dev_estimate + (1.0 - ema) * twins.freq_dev
    return twins._replace(dev_estimate=est)


def calibrated_freq(twins: TwinState) -> jnp.ndarray:
    return twins.freq + twins.dev_estimate


def observe_round(twins: TwinState, losses, energies, malicious_mask=None
                  ) -> TwinState:
    """Update twins after a federated round (real-time mapping)."""
    mal = (jnp.zeros_like(twins.beta) if malicious_mask is None
           else malicious_mask.astype(jnp.float32))
    return twins._replace(
        loss=losses,
        energy=twins.energy + energies,
        alpha=twins.alpha + (1.0 - mal),
        beta=twins.beta + mal,
    )


# ------------------------------------------------------------------ #
# fixed-shape member views for the fused FleetState round
# ------------------------------------------------------------------ #
def member_view(twins: TwinState, members) -> TwinState:
    """Gather a (M,) member slice of every twin array, jit-safely.

    ``members`` may hold the out-of-range padding sentinel n; those slots
    fill with neutral values (alpha=1 so the Eqn-4 interaction ratio stays
    finite) and must be masked by the caller before any reduction.
    """
    def take(x, fill):
        return x.at[members].get(mode="fill", fill_value=fill)

    return TwinState(
        loss=take(twins.loss, 0.0), freq=take(twins.freq, 1.0),
        freq_dev=take(twins.freq_dev, 0.0),
        dev_estimate=take(twins.dev_estimate, 0.0),
        energy=take(twins.energy, 0.0), data_size=take(twins.data_size, 1.0),
        alpha=take(twins.alpha, 1.0), beta=take(twins.beta, 0.0),
        router_entropy=take(twins.router_entropy, 0.0))


def observe_round_members(twins: TwinState, members, losses, energies,
                          malicious_mask=None) -> TwinState:
    """`observe_round` driven by one cluster's (M,) member slice.

    Scatters the member losses/energies into the fleet (padding sentinels
    drop) and applies the fleet-wide interaction-count update exactly as
    `observe_round` does.
    """
    full_loss = twins.loss.at[members].set(losses, mode="drop")
    full_e = jnp.zeros_like(twins.energy).at[members].set(
        energies, mode="drop")
    return observe_round(twins, full_loss, full_e, malicious_mask)
