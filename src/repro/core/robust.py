"""Robust-aggregation baselines (the literature the paper positions
against: §II "aggregation strategy").

The paper's trust-weighted aggregation (Eqns 4-6) is compared in
benchmarks/attack_bench.py against the standard Byzantine-robust rules:

  krum / multi-krum   (Blanchard et al., 2017)
  coordinate median   (Yin et al., 2018)
  trimmed mean        (Yin et al., 2018)
  fedavg              (unweighted mean — the vulnerable baseline)

All operate on a pytree with leading client dim, like
trust.trust_weighted_average.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _flat(tree):
    leaves = jax.tree.leaves(tree)
    C = leaves[0].shape[0]
    return jnp.concatenate([x.reshape(C, -1).astype(jnp.float32)
                            for x in leaves], axis=1)


def _unflat_like(vec, tree):
    leaves, treedef = jax.tree.flatten(tree)
    out, off = [], 0
    for x in leaves:
        n = x[0].size
        out.append(vec[off:off + n].reshape(x.shape[1:]).astype(x.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def krum_scores(flat, f: int):
    """Sum of distances to the C-f-2 nearest neighbours, per client."""
    C = flat.shape[0]
    d2 = jnp.sum((flat[:, None] - flat[None]) ** 2, axis=-1)     # (C,C)
    d2 = jnp.where(jnp.eye(C, dtype=bool), jnp.inf, d2)   # (0*inf = nan!)
    k = max(1, C - f - 2)
    nearest = jnp.sort(d2, axis=1)[:, :k]
    return nearest.sum(axis=1)


def krum(client_params, f: int = 1):
    """Select the single client closest to its neighbours (Krum)."""
    flat = _flat(client_params)
    best = jnp.argmin(krum_scores(flat, f))
    return jax.tree.map(lambda x: x[best], client_params)


def multi_krum(client_params, f: int = 1, m: int | None = None):
    """Average the m lowest-score clients (Multi-Krum)."""
    flat = _flat(client_params)
    C = flat.shape[0]
    m = m or max(1, C - f)
    scores = krum_scores(flat, f)
    sel = jnp.argsort(scores)[:m]
    mean = flat[sel].mean(axis=0)
    return _unflat_like(mean, client_params)


def coordinate_median(client_params):
    flat = _flat(client_params)
    return _unflat_like(jnp.median(flat, axis=0), client_params)


def masked_coordinate_median(client_params, mask):
    """Coordinate median over the ``mask``-valid client rows, at fixed shape.

    Padded rows are replaced with +inf so an ascending sort pushes them past
    the n valid entries; the median is then read at the traced indices
    (n-1)//2 and n//2 of the sorted prefix — the same two-middle average
    `jnp.median` takes on the compacted rows.  This is what lets `median`
    join the padded fused round (`supports_mask=True`) instead of compiling
    one exact-shape round per cluster size.
    """
    flat = _flat(client_params)
    big = jnp.where(mask[:, None], flat, jnp.inf)
    s = jnp.sort(big, axis=0)
    n = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)
    lo = jnp.take(s, (n - 1) // 2, axis=0)
    hi = jnp.take(s, n // 2, axis=0)
    return _unflat_like(0.5 * (lo + hi), client_params)


def trimmed_mean(client_params, beta: float = 0.2):
    """Drop the beta fraction of extremes per coordinate, then average."""
    flat = _flat(client_params)
    C = flat.shape[0]
    k = int(C * beta)
    s = jnp.sort(flat, axis=0)
    s = s[k:C - k] if C - 2 * k >= 1 else s
    return _unflat_like(s.mean(axis=0), client_params)


def masked_trimmed_mean(client_params, mask, beta: float = 0.2):
    """Trimmed mean over the ``mask``-valid client rows, at fixed shape.

    The same ±inf-padded-sort construction as `masked_coordinate_median`:
    padded rows sort past the n valid entries, so ranks [k, n-k) of the
    sorted prefix are exactly the coordinates `trimmed_mean` keeps on the
    compacted rows, with k = floor(n·beta) re-derived from the traced valid
    count (and the k = 0 fallback when trimming would drop everything).
    This gives `trimmed_mean` ``supports_mask=True``: one padded fused-round
    compile instead of one exact-shape compile per cluster size.
    """
    flat = _flat(client_params)
    big = jnp.where(mask[:, None], flat, jnp.inf)
    s = jnp.sort(big, axis=0)
    n = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)
    k = jnp.floor(n.astype(jnp.float32) * beta).astype(jnp.int32)
    k = jnp.where(n - 2 * k >= 1, k, 0)
    ranks = jnp.arange(s.shape[0], dtype=jnp.int32)[:, None]
    keep = ((ranks >= k) & (ranks < n - k)).astype(jnp.float32)
    mean = jnp.sum(jnp.where(keep > 0, s, 0.0), axis=0) / jnp.maximum(
        n - 2 * k, 1).astype(jnp.float32)
    return _unflat_like(mean, client_params)


AGGREGATORS = {
    "krum": krum,
    "multi_krum": multi_krum,
    "median": coordinate_median,
    "trimmed_mean": trimmed_mean,
}

# rules with a fixed-capacity masked variant: these can run on the engine's
# padded fixed-shape clusters (supports_mask=True) instead of forcing one
# exact-shape compile per cluster size
MASKED_AGGREGATORS = {
    "median": masked_coordinate_median,
    "trimmed_mean": masked_trimmed_mean,
}
