"""Differential privacy for federated updates.

Privacy is the paper's stated motivation for FL in Industrial IoT
(§I: "data islands ... privacy and security issues"); this module provides
the standard client-level DP mechanism for the update pipeline:

    clip each client's model delta to L2 <= clip_norm, then add
    N(0, (noise_multiplier * clip_norm / C)^2) to the aggregate.

Exposed as an option on AsyncFederation (dp_clip/dp_noise in AsyncFLConfig)
and usable standalone around any pytree of updates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_update(update, clip_norm: float):
    """Scale a pytree update to L2 norm <= clip_norm."""
    g2 = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(update))
    scale = jnp.minimum(1.0, clip_norm / (jnp.sqrt(g2) + 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), update)


def clip_client_updates(client_updates, clip_norm: float):
    """Vectorized clip over the leading client dim."""
    def per_client(tree):
        return clip_update(tree, clip_norm)
    return jax.vmap(per_client)(client_updates)


def add_gaussian_noise(key, aggregate, clip_norm: float,
                       noise_multiplier: float, n_clients):
    """Add the DP Gaussian mechanism's noise to an aggregated update.
    ``n_clients`` may be a traced scalar (fused fixed-shape rounds pass the
    true member count, not the padded one)."""
    sigma = noise_multiplier * clip_norm / jnp.maximum(n_clients, 1)
    leaves, treedef = jax.tree.flatten(aggregate)
    keys = jax.random.split(key, len(leaves))
    noised = [x + sigma * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype)
              for x, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noised)


def dp_aggregate(key, client_params, global_params, weights,
                 clip_norm: float, noise_multiplier: float, n_clients=None):
    """Trust-weighted DP aggregation: clip per-client deltas, weight,
    combine, noise.  Composes the paper's Eqn 6 with client-level DP.
    ``n_clients`` overrides the noise denominator when ``weights`` carries
    zero-weight padding rows (defaults to the leading dim)."""
    deltas = jax.tree.map(lambda c, g: c - g[None].astype(c.dtype),
                          client_params, global_params)
    deltas = clip_client_updates(deltas, clip_norm)
    w = weights.reshape((-1,) + (1,) * 0)
    agg = jax.tree.map(
        lambda d: jnp.einsum("c...,c->...", d.astype(jnp.float32),
                             w.astype(jnp.float32)),
        deltas)
    agg = add_gaussian_noise(
        key, agg, clip_norm, noise_multiplier,
        weights.shape[0] if n_clients is None else n_clients)
    return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(g.dtype),
                        global_params, agg)
