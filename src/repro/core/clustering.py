"""K-means node clustering for asynchronous FL (paper §IV-D step 1).

Clusters devices by (data size, compute power) so same-cluster nodes have
similar local-training wall time — eliminating the straggler effect.  Pure
JAX (lax.fori_loop Lloyd iterations) so it can consume TwinState directly.

`ensure_nonempty` and `padded_membership` turn a k-means assignment into the
fixed-shape fleet tables the fused `FleetState` round consumes: Lloyd
iterations can abandon a centroid, and a memberless cluster used to crash
the engine (np.stack([]) in the old per-member loop) — re-seeding from the
largest cluster keeps every event-heap entry schedulable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .twin import TwinState, calibrated_freq


def _normalize(x):
    mu = x.mean(0, keepdims=True)
    sd = x.std(0, keepdims=True) + 1e-8
    return (x - mu) / sd


def kmeans(key, feats, k: int, iters: int = 25):
    """feats: (n, d) -> (assignments (n,), centroids (k, d))."""
    n = feats.shape[0]
    init_idx = jax.random.choice(key, n, (k,), replace=False)
    cent = feats[init_idx]

    def body(_, cent):
        d2 = jnp.sum((feats[:, None] - cent[None]) ** 2, axis=-1)   # (n,k)
        assign = jnp.argmin(d2, axis=1)
        oh = jax.nn.one_hot(assign, k)                              # (n,k)
        cnt = oh.sum(0)[:, None]
        new = (oh.T @ feats) / jnp.maximum(cnt, 1.0)
        return jnp.where(cnt > 0, new, cent)

    cent = jax.lax.fori_loop(0, iters, body, cent)
    d2 = jnp.sum((feats[:, None] - cent[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1), cent


def cluster_devices(key, twins: TwinState, k: int):
    """Cluster by (data size, calibrated compute power) per the paper."""
    feats = _normalize(jnp.stack(
        [twins.data_size, calibrated_freq(twins)], axis=1))
    return kmeans(key, feats, k)


def ensure_nonempty(assign, k: int):
    """Re-seed memberless clusters so every cluster owns >= 1 device.

    K-means can converge with an abandoned centroid; a memberless cluster
    has no defined round duration and used to crash the engine.  Each empty
    cluster deterministically steals the first device of the currently
    largest cluster (host-side, init-time only).  Requires n >= k.
    """
    assign = np.asarray(assign).copy()
    if assign.shape[0] < k:
        raise ValueError(f"cannot fill {k} clusters from {assign.shape[0]} "
                         "devices")
    counts = np.bincount(assign, minlength=k)
    for c in range(k):
        if counts[c] == 0:
            donor = int(counts.argmax())
            i = int(np.where(assign == donor)[0][0])
            assign[i] = c
            counts[donor] -= 1
            counts[c] += 1
    return assign


def padded_membership(assign, k: int):
    """Fixed-shape membership tables for the fused cluster round.

    -> (member_table (k, M) int32, mask (k, M) bool) with M = max cluster
    size.  Padding slots hold the out-of-range sentinel ``n`` so jitted
    gathers use mode='fill' and scatters use mode='drop' — ragged cluster
    memberships then run as one fixed-shape grid per round.
    """
    assign = np.asarray(assign)
    n = assign.shape[0]
    # vectorized grouping (the per-cluster np.where loop was O(n*k) —
    # minutes at the capacity benchmark's n=10^6): one stable sort by
    # cluster, then each cluster's members are a contiguous run.  Stable
    # sort keeps ids ascending within a cluster, exactly like np.where.
    order = np.argsort(assign, kind="stable").astype(np.int32)
    counts = np.bincount(assign, minlength=k)
    m = int(counts.max()) if n else 0
    width = max(m, 1)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    col = np.arange(n) - np.repeat(starts, counts)      # slot within row
    table = np.full((k, width), n, dtype=np.int32)
    mask = np.zeros((k, width), dtype=bool)
    rows = np.repeat(np.arange(k), counts)
    table[rows, col] = order
    mask[rows, col] = True
    return jnp.asarray(table), jnp.asarray(mask)


def tolerance_bound(a, freq, t_min, alpha: float):
    """Alg. 2 lines 4-6: cap local-update counts so a_i / f_i <= alpha*T_m
    relative to the fastest cluster's local-update time T_m."""
    t_local = a.astype(jnp.float32) / jnp.maximum(freq, 1e-6)
    cap = jnp.floor(alpha * t_min * freq).astype(jnp.int32)
    return jnp.where(t_local > alpha * t_min, jnp.maximum(cap, 1), a)
