"""K-means node clustering for asynchronous FL (paper §IV-D step 1).

Clusters devices by (data size, compute power) so same-cluster nodes have
similar local-training wall time — eliminating the straggler effect.  Pure
JAX (lax.fori_loop Lloyd iterations) so it can consume TwinState directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .twin import TwinState, calibrated_freq


def _normalize(x):
    mu = x.mean(0, keepdims=True)
    sd = x.std(0, keepdims=True) + 1e-8
    return (x - mu) / sd


def kmeans(key, feats, k: int, iters: int = 25):
    """feats: (n, d) -> (assignments (n,), centroids (k, d))."""
    n = feats.shape[0]
    init_idx = jax.random.choice(key, n, (k,), replace=False)
    cent = feats[init_idx]

    def body(_, cent):
        d2 = jnp.sum((feats[:, None] - cent[None]) ** 2, axis=-1)   # (n,k)
        assign = jnp.argmin(d2, axis=1)
        oh = jax.nn.one_hot(assign, k)                              # (n,k)
        cnt = oh.sum(0)[:, None]
        new = (oh.T @ feats) / jnp.maximum(cnt, 1.0)
        return jnp.where(cnt > 0, new, cent)

    cent = jax.lax.fori_loop(0, iters, body, cent)
    d2 = jnp.sum((feats[:, None] - cent[None]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1), cent


def cluster_devices(key, twins: TwinState, k: int):
    """Cluster by (data size, calibrated compute power) per the paper."""
    feats = _normalize(jnp.stack(
        [twins.data_size, calibrated_freq(twins)], axis=1))
    return kmeans(key, feats, k)


def tolerance_bound(a, freq, t_min, alpha: float):
    """Alg. 2 lines 4-6: cap local-update counts so a_i / f_i <= alpha*T_m
    relative to the fastest cluster's local-update time T_m."""
    t_local = a.astype(jnp.float32) / jnp.maximum(freq, 1e-6)
    cap = jnp.floor(alpha * t_min * freq).astype(jnp.int32)
    return jnp.where(t_local > alpha * t_min, jnp.maximum(cap, 1), a)
