"""DQN for adaptive aggregation-frequency calibration (paper §IV-B/C, Alg. 1).

Pure-JAX DQN matching the paper's setup: two identical fully-connected
networks (eval_net O and target_net O'), sized 48 x 200 x 10 by default
(state dim x single hidden layer with 200 neurons x |actions|), experience
replay, epsilon-greedy with a growing greed coefficient, periodic target-net
sync, TD loss Eqns 16-18 optimized by SGD.

Actions index the number of local updates a_i in {1..n_actions} between global
aggregations.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class DQNConfig(NamedTuple):
    state_dim: int = 48
    hidden: int = 200
    n_actions: int = 10
    gamma: float = 0.9            # attenuation coefficient (paper §IV-B)
    lr: float = 1e-3
    buffer_size: int = 2048
    batch_size: int = 64
    target_sync: int = 50         # F_u: target_net update frequency
    eps0: float = 0.1             # initial greed coefficient
    eps_growth: float = 1e-3      # r: greed growth rate per step (-> 1.0)


class Replay(NamedTuple):
    s: jnp.ndarray       # (cap, state_dim)
    a: jnp.ndarray       # (cap,) int32
    r: jnp.ndarray       # (cap,)
    s2: jnp.ndarray      # (cap, state_dim)
    ptr: jnp.ndarray     # scalar int32
    full: jnp.ndarray    # scalar bool


class DQNState(NamedTuple):
    eval_params: dict
    target_params: dict
    replay: Replay
    step: jnp.ndarray    # scalar int32


def _init_net(key, cfg: DQNConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda n: 1.0 / jnp.sqrt(n)
    return {
        "w1": jax.random.normal(k1, (cfg.state_dim, cfg.hidden)) * s(cfg.state_dim),
        "b1": jnp.zeros((cfg.hidden,)),
        "w2": jax.random.normal(k2, (cfg.hidden, cfg.hidden)) * s(cfg.hidden),
        "b2": jnp.zeros((cfg.hidden,)),
        "w3": jax.random.normal(k3, (cfg.hidden, cfg.n_actions)) * s(cfg.hidden),
        "b3": jnp.zeros((cfg.n_actions,)),
    }


def q_values(params, s):
    """Three fully-connected layers (paper §V network)."""
    h = jax.nn.relu(s @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    return h @ params["w3"] + params["b3"]


def init_dqn(key, cfg: DQNConfig) -> DQNState:
    ke, _ = jax.random.split(key)
    eval_p = _init_net(ke, cfg)
    cap = cfg.buffer_size
    rep = Replay(s=jnp.zeros((cap, cfg.state_dim)),
                 a=jnp.zeros((cap,), jnp.int32),
                 r=jnp.zeros((cap,)),
                 s2=jnp.zeros((cap, cfg.state_dim)),
                 ptr=jnp.zeros((), jnp.int32),
                 full=jnp.zeros((), bool))
    return DQNState(eval_params=eval_p,
                    target_params=jax.tree.map(jnp.copy, eval_p),
                    replay=rep, step=jnp.zeros((), jnp.int32))


def epsilon(cfg: DQNConfig, step):
    """Greed coefficient grows from eps0 toward 1 at rate r (Alg. 1 input)."""
    return jnp.minimum(cfg.eps0 + cfg.eps_growth * step.astype(jnp.float32), 1.0)


def select_action(key, state: DQNState, cfg: DQNConfig, s):
    """epsilon-greedy (Alg. 1 line 5): greedy w.p. eps, random otherwise."""
    kg, kr = jax.random.split(key)
    greedy = jnp.argmax(q_values(state.eval_params, s))
    rand = jax.random.randint(kr, (), 0, cfg.n_actions)
    use_greedy = jax.random.uniform(kg) < epsilon(cfg, state.step)
    return jnp.where(use_greedy, greedy, rand).astype(jnp.int32)


def store(state: DQNState, s, a, r, s2) -> DQNState:
    rep = state.replay
    i = rep.ptr
    rep = rep._replace(
        s=rep.s.at[i].set(s), a=rep.a.at[i].set(a),
        r=rep.r.at[i].set(r), s2=rep.s2.at[i].set(s2),
        ptr=(i + 1) % rep.s.shape[0],
        full=rep.full | (i + 1 >= rep.s.shape[0]))
    return state._replace(replay=rep)


def _td_loss(eval_params, target_params, cfg: DQNConfig, batch):
    s, a, r, s2 = batch
    q = q_values(eval_params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    # Eqn 17: y = r + gamma max_a' O(s', a'; w^-)
    q2 = q_values(target_params, s2)
    y = r + cfg.gamma * jnp.max(q2, axis=1)
    y = jax.lax.stop_gradient(y)
    # Eqn 16
    return jnp.mean((y - q_sa) ** 2)


def train_step_fn(key, state: DQNState, cfg: DQNConfig) -> tuple:
    """One Alg.-1 learning iteration: sample replay, SGD on TD loss
    (Eqn 18), periodic target sync.  Returns (state, loss).

    Pure and unjitted so `repro.control.scanned_dqn` can trace it inside a
    `lax.scan` step; `train_step` below is the jitted entry point for
    host-driven loops."""
    rep = state.replay
    cap = rep.s.shape[0]
    limit = jnp.where(rep.full, cap, jnp.maximum(rep.ptr, 1))
    idx = jax.random.randint(key, (cfg.batch_size,), 0, limit)
    batch = (rep.s[idx], rep.a[idx], rep.r[idx], rep.s2[idx])

    loss, grads = jax.value_and_grad(_td_loss)(
        state.eval_params, state.target_params, cfg, batch)
    # clip: TD targets can spike when the deficit queue builds up
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, 5.0 / (gnorm + 1e-9))
    eval_p = jax.tree.map(lambda p, g: p - cfg.lr * scale * g,
                          state.eval_params, grads)

    sync = (state.step % cfg.target_sync) == 0
    target_p = jax.tree.map(
        lambda t, e: jnp.where(sync, e, t), state.target_params, eval_p)
    return state._replace(eval_params=eval_p, target_params=target_p,
                          step=state.step + 1), loss


train_step = functools.partial(jax.jit, static_argnums=2)(train_step_fn)
