"""Energy and channel models (paper §III-D, Eqns 7-8).

Compute energy per local training (Eqn 7):   E_cmp = n_cmp * F / f_i
OFDMA uplink communication energy (Eqn 8):
    E_com = n_com * M / sum_c l_{i,c} W log2(1 + p h / I)

The wireless channel follows the paper's §V setup: a finite-state Markov
channel over {good, medium, bad} whose noise means are {0.1, 0.3, 0.5} dB
(Poisson-distributed noise influence).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

GOOD, MEDIUM, BAD = 0, 1, 2
NOISE_MEAN_DB = jnp.array([0.1, 0.3, 0.5])


class ChannelParams(NamedTuple):
    bandwidth: float = 1e5          # W: sub-channel bandwidth [Hz]
    n_subchannels: int = 8          # |C|
    tx_power: float = 0.2           # p_{i,c} [W]
    gain: float = 1.0               # h_{i,c}
    model_bits: float = 8e6         # M: model size [bits]
    n_com: float = 1.0              # comm normalization factor
    n_cmp: float = 1.0              # compute normalization factor
    train_cycles: float = 1.0       # F: CPU cycles for one local training [G]
    # defaults put E_com on the same order as E_cmp so the channel state
    # actually drives the aggregation-timing trade-off (paper §V regime)


def compute_energy(freq, params: ChannelParams = ChannelParams()):
    """Eqn 7 per local training, vectorized over clients. freq: (n,) [GHz]."""
    return params.n_cmp * params.train_cycles / jnp.maximum(freq, 1e-3)


def channel_rate(state, key, params: ChannelParams = ChannelParams(),
                 members=None):
    """Shannon rate per client given channel state (n,) in {0,1,2}.
    Noise ~ Poisson with the state's mean influence (paper §V).

    With ``members`` (the device ids behind each slot of ``state``) the
    noise draw is keyed per device id via `fold_in` instead of shaped by
    ``state.shape`` — a device's channel noise is then invariant to the
    padded membership width, which is what pins padded, sharded, and
    population-stacked rounds to the same realization."""
    lam = NOISE_MEAN_DB[state]
    if members is None:
        noise = jax.random.poisson(key, lam, state.shape)
    else:
        noise = jax.vmap(
            lambda m, l: jax.random.poisson(jax.random.fold_in(key, m),
                                            l, ()))(members, lam)
    noise_db = noise.astype(jnp.float32) + lam
    noise = 10.0 ** (noise_db / 10.0) * 1e-7
    snr = params.tx_power * params.gain / noise
    frac = 1.0 / params.n_subchannels
    return params.n_subchannels * frac * params.bandwidth * jnp.log2(1.0 + snr)


def comm_energy(state, key, params: ChannelParams = ChannelParams(),
                members=None):
    """Eqn 8 per aggregation upload, vectorized over clients."""
    rate = channel_rate(state, key, params, members=members)
    return params.n_com * params.model_bits / jnp.maximum(rate, 1.0)


def round_energy(a, true_freq, channel_state, key,
                 params: ChannelParams = ChannelParams(), members=None):
    """Eqns 7+8 for one cluster round: ``a`` local trainings plus one
    upload, per member.  ``a`` may be a traced scalar (the fused round
    applies the Alg.-2 tolerance bound inside jit); ``true_freq`` is the
    device's real frequency f + f̂ (the twin's mapped value plus deviation).
    ``members`` keys the channel-noise draw per device id (see
    `channel_rate`)."""
    e_cmp = a * compute_energy(true_freq, params)
    e_com = comm_energy(channel_state, key, params, members=members)
    return e_cmp + e_com


# ------------------------------------------------------------------ #
# finite-state Markov channel
# ------------------------------------------------------------------ #
def channel_transition(p_good: float):
    """3-state transition matrix parameterized by the stationary probability
    of the good state (benchmarks sweep p_good as in Fig. 4)."""
    rest = (1.0 - p_good) / 2.0
    row = jnp.array([p_good, rest, rest])
    return jnp.stack([row, row, row])


def step_channel(key, state, trans):
    """state: (n,) int; trans: (3,3) row-stochastic."""
    return jax.random.categorical(key, jnp.log(trans[state] + 1e-12), axis=-1)
