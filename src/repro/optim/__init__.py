from .optimizers import (Optimizer, sgd, adam, adamw, adafactor,
                         apply_updates, global_norm, clip_by_global_norm)

__all__ = ["Optimizer", "sgd", "adam", "adamw", "adafactor",
           "apply_updates", "global_norm", "clip_by_global_norm"]
