"""Pure-JAX optimizers (no optax available offline).

Functional interface mirroring optax:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

All states are pytrees matching params, so they vmap over the FL client
dimension (mode A) and shard like the parameters they track.  ``adafactor``
keeps factored second moments (rows/cols) — the memory-frugal choice for the
314B/236B architectures (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


# --------------------------------------------------------------------- #
def sgd(lr, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        return jax.tree.map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


# --------------------------------------------------------------------- #
def adam(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            step = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                step = step - lr * weight_decay * p.astype(jnp.float32)
            return step

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), m, v)
        else:
            updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)


# --------------------------------------------------------------------- #
def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, sequential: bool = False,
              compute_dtype=None) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018).

    For leaves with ndim >= 2 the last two dims are factored into row/col
    accumulators; smaller leaves keep a full accumulator.  State is O(n+m)
    per (n, m) matrix — what lets grok-1/deepseek-v2 train on a 16 GB/chip
    pod (DESIGN.md §5).

    ``sequential=True`` chains leaf updates through
    ``lax.optimization_barrier`` so XLA cannot overlap the fp32 update
    temporaries of every leaf at once — measured to be the difference
    between ~46 GB and fitting HBM on grok-1 train (EXPERIMENTS.md §Perf)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"acc": jax.tree.map(leaf, params,
                                    is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        t = state["t"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** -decay

        def leaf(g, acc):
            g = g.astype(compute_dtype or jnp.float32)
            g2 = jnp.square(g) + eps
            if "r" in acc:
                r = beta * acc["r"] + (1 - beta) * g2.mean(axis=-1).astype(jnp.float32)
                c = beta * acc["c"] + (1 - beta) * g2.mean(axis=-2).astype(jnp.float32)
                rc = r / jnp.maximum(r.mean(axis=-1, keepdims=True), eps)
                vhat = (rc[..., None] * c[..., None, :]).astype(g.dtype)
                new = {"r": r, "c": c}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                vhat = v
                new = {"v": v}
            u = g / jnp.sqrt(vhat + eps)
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_a = treedef.flatten_up_to(state["acc"])
        out = []
        prev = None
        for g, a in zip(flat_g, flat_a):
            if sequential and prev is not None:
                # serialize: this leaf's grad depends on the previous
                # leaf's finished update, bounding transient liveness
                prev, g = jax.lax.optimization_barrier((prev, g))
            u, new_acc = leaf(g, a)
            prev = u
            out.append((u, new_acc))
        updates = treedef.unflatten([o[0] for o in out])
        acc = treedef.unflatten([o[1] for o in out])
        return updates, {"acc": acc, "t": t}

    return Optimizer(init, update)


REGISTRY = {"sgd": sgd, "adam": adam, "adamw": adamw, "adafactor": adafactor}
