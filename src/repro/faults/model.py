"""`FaultModel`: the in-jit compilation of a `FaultSpec`.

Built once at engine init, applied inside `DeviceScaleEngine._fleet_round`
— every method here is pure jnp over fixed shapes, so the fault program
traces into the fused per-event round, the `lax.scan`-over-rounds lowering,
and the mesh-sharded jits alike (the static device-subset tables ride
along as captured constants, exactly like the engine's malicious mask).

Randomness discipline: the engine hands each round one fault key ``kf``
(split off the `FleetState` key only when the spec is active, so inert
specs consume the exact pre-fault RNG stream), and each fault family folds
a fixed tag into it — families never perturb each other's draws, and
toggling one family leaves the others' realizations unchanged at a fixed
fault seed.

The Byzantine subsets (update corruption / input poisoning) are *static*:
``int(frac * n)`` devices drawn once from ``FaultSpec.seed`` at build time,
mirroring the engine's ``malicious_frac`` machinery — a compromised device
stays compromised, which is what gives the Eqn-4/5 reputation its signal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .spec import FaultSpec

# per-family fold_in tags: stable, so enabling one family never shifts
# another family's per-round draws
_TAG_DROP, _TAG_STRAGGLE, _TAG_SPIKE, _TAG_CORRUPT, _TAG_POISON = range(5)


def _member_uniform(key, members):
    """One U[0,1) per member slot, keyed by the slot's *device id* via
    `fold_in` — never by the slot's position or the row's width.  A
    width-shaped draw (``uniform(key, mask.shape)``) realizes different
    values whenever the padded membership width changes; keying by id makes
    the stream invariant, so exact-shape, padded, sharded, and
    population-stacked rounds all see the same per-device faults.  Padding
    slots (the sentinel id) draw too, and are masked downstream."""
    return jax.vmap(
        lambda m: jax.random.uniform(jax.random.fold_in(key, m), ()))(
            members)


def _static_subset(rng: np.random.Generator, n: int, frac: float
                   ) -> jnp.ndarray:
    """(n,) f32 indicator of a fixed ``int(frac*n)``-device subset."""
    out = np.zeros((n,), np.float32)
    k = int(frac * n)
    if k:
        out[rng.choice(n, size=k, replace=False)] = 1.0
    return jnp.asarray(out)


class FaultModel:
    """Pure-jnp fault transformations for one fleet (see module docstring).

    Mirrors the `FaultSpec` ``may_*``/``active`` flags so the engine can
    gate each family *statically* — a disabled family contributes zero ops
    (and zero RNG consumption) to the compiled round.
    """

    def __init__(self, spec: FaultSpec, n_devices: int):
        self.spec = spec.validate()
        self.n = int(n_devices)
        # the two Byzantine subsets draw from independent streams of the
        # fault seed so enabling poisoning never reshuffles the corrupters
        self.corrupt_dev = _static_subset(
            np.random.default_rng((spec.seed, _TAG_CORRUPT)), self.n,
            spec.corrupt_frac if spec.may_corrupt else 0.0)
        self.poison_dev = _static_subset(
            np.random.default_rng((spec.seed, _TAG_POISON)), self.n,
            spec.poison_frac if spec.may_poison else 0.0)
        # fold the fault seed into every per-round key so two FaultSpecs
        # differing only in `seed` realize different fault streams against
        # the same federation randomness
        self._seed = int(spec.seed)

    # convenience mirrors ---------------------------------------------- #
    def stats(self) -> dict:
        """Static bookkeeping for telemetry gauges (`repro.obs`): the
        realized Byzantine subset sizes plus the per-family rates.  All
        build-time constants — realized *in-jit* draws are deliberately
        not counted, since surfacing them would require new scan outputs
        and break the instrumented/uninstrumented trace bit-parity the
        telemetry layer guarantees."""
        s = self.spec
        return {
            "active": float(self.active),
            "corrupt_devices": float(np.sum(np.asarray(self.corrupt_dev))),
            "poison_devices": float(np.sum(np.asarray(self.poison_dev))),
            "dropout_rate": float(s.dropout) if self.may_drop else 0.0,
            "straggler_frac": (float(s.straggler_frac)
                               if self.may_straggle else 0.0),
            "twin_spike_prob": (float(s.twin_spike_prob)
                                if self.may_spike else 0.0),
        }

    @property
    def active(self) -> bool:
        return self.spec.active

    @property
    def may_drop(self) -> bool:
        return self.spec.may_drop

    @property
    def may_straggle(self) -> bool:
        return self.spec.may_straggle

    @property
    def may_spike(self) -> bool:
        return self.spec.may_spike

    @property
    def may_corrupt(self) -> bool:
        return self.spec.may_corrupt

    @property
    def may_poison(self) -> bool:
        return self.spec.may_poison

    # ------------------------------------------------------------------ #
    # in-jit per-round transformations (kf: the round's fault key)
    # ------------------------------------------------------------------ #
    def _key(self, kf, tag: int):
        return jax.random.fold_in(jax.random.fold_in(kf, self._seed), tag)

    def drop_mask(self, kf, mask: jnp.ndarray, members) -> jnp.ndarray:
        """Bernoulli(dropout) participation failure per member slot."""
        u = _member_uniform(self._key(kf, _TAG_DROP), members)
        return mask & (u >= self.spec.dropout)

    def straggle(self, kf, dur, mask: jnp.ndarray, members):
        """Any straggling member multiplies the cluster round duration by
        ``straggler_factor`` — the straggler gates the synchronous local
        phase, matching Alg. 2's min-frequency convention."""
        u = _member_uniform(self._key(kf, _TAG_STRAGGLE), members)
        st = (u < self.spec.straggler_frac) & mask
        return dur * jnp.where(jnp.any(st),
                               jnp.float32(self.spec.straggler_factor),
                               jnp.float32(1.0))

    def spike_twins(self, kf, tw_m, mask: jnp.ndarray, members):
        """Amplify the DT mapping deviation f̂ of spiked members in the
        (M,)-sliced twin view feeding Eqn 4 — the trust rule's
        deviation-normalized belief is what must absorb this."""
        u = _member_uniform(self._key(kf, _TAG_SPIKE), members)
        sp = (u < self.spec.twin_spike_prob) & mask
        scale = jnp.float32(self.spec.twin_spike_scale)
        return tw_m._replace(
            freq_dev=jnp.where(sp, tw_m.freq_dev * scale, tw_m.freq_dev))

    def corrupt_updates(self, kf, new, stacked, members):
        """Byzantine update corruption on the static corrupt subset,
        applied to the per-member *deltas* (new - stacked) before trust /
        aggregation, via the same gather-with-fill the padded round uses
        everywhere (padding sentinels gather weight 0)."""
        cz = self.corrupt_dev.at[members].get(mode="fill", fill_value=0.0)
        kc = self._key(kf, _TAG_CORRUPT)
        mode = self.spec.corrupt_mode
        scale = self.spec.corrupt_scale
        flat_new, treedef = jax.tree_util.tree_flatten(new)
        flat_old = jax.tree_util.tree_leaves(stacked)
        out = []
        for i, (nl, sl) in enumerate(zip(flat_new, flat_old)):
            upd = nl - sl
            if mode == "sign_flip":
                # scaled sign flip: the classic model-replacement attack
                # pushes against the honest direction, amplified
                bad = -upd * jnp.asarray(scale, upd.dtype)
            elif mode == "scaled_norm":
                bad = upd * jnp.asarray(scale, upd.dtype)
            else:                                       # gaussian
                # noise sized relative to each member's own update norm
                # (raw per-element noise over the full parameter vector is
                # ~sqrt(P) times the update and vaporizes the model in one
                # round — no aggregator could demonstrate recovery)
                axes = tuple(range(1, upd.ndim))
                nrm = jnp.sqrt(jnp.sum(upd * upd, axis=axes,
                                       keepdims=True) + 1e-12)
                sz = float(np.prod(upd.shape[1:])) or 1.0
                # per-device keys (fold the member id, not the slot): the
                # noise a device sees is invariant to the padded row width,
                # like every other in-jit fault draw here
                ki = jax.random.fold_in(kc, i)
                noise = jax.vmap(
                    lambda m: jax.random.normal(
                        jax.random.fold_in(ki, m), upd.shape[1:],
                        upd.dtype))(members)
                bad = upd + (jnp.asarray(scale, upd.dtype) * nrm
                             / jnp.asarray(np.sqrt(sz), upd.dtype)) * noise
            w = cz.reshape((-1,) + (1,) * (upd.ndim - 1)).astype(upd.dtype)
            out.append(sl + upd + w * (bad - upd))
        return jax.tree_util.tree_unflatten(treedef, out)

    def poison_inputs(self, kf, x, members):
        """Fixed-pattern input poisoning on the static poison subset: each
        poisoned device adds ``poison_scale`` times its own frozen random
        bias vector to every feature it trains on — a miscalibrated /
        stuck-sensor model.  A *consistent* bias is the damaging variant:
        the model can (and does) learn it, dragging the decision surface,
        where fresh per-round noise would average out to a no-op.  For
        reconstruction tasks (labels never in the loss) this is the only
        attack surface; the defense signals are the poisoned members'
        mutually-aligned divergent gradients (Eqn 4 quality + FoolsGold)
        and the accumulating negative-interaction tally."""
        pz = self.poison_dev.at[members].get(mode="fill", fill_value=0.0)
        feat = x.shape[-1]
        # per-device patterns derive from the build-time seed only — the
        # same device injects the same bias every round
        patterns = jax.random.normal(
            jax.random.PRNGKey(self._seed * 2654435761 % (2**31)),
            (self.n + 1, feat), x.dtype)
        p_m = patterns.at[jnp.clip(members, 0, self.n)].get()
        w = pz.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        bias = p_m.reshape((p_m.shape[0],) + (1,) * (x.ndim - 2) + (feat,))
        return x + w * jnp.asarray(self.spec.poison_scale, x.dtype) * bias
