"""`FaultSpec`: declarative fault injection for a federation experiment.

Failure is spec data, exactly like placement (`ShardingSpec`): a plain
dataclass with dict/JSON round-trip that `repro.faults.model.FaultModel`
compiles into pure-jnp transformations applied *inside* the fused round —
so the same fault program runs on the event-heap, scanned, and mesh-sharded
execution paths without any per-path code.

Five orthogonal fault families, all off by default (the default spec is
inert: the engine compiles the exact pre-fault round):

dropout          per-member per-round Bernoulli participation failure.  A
                 dropped member leaves the round's padded mask; a round
                 whose cluster empties entirely is *skipped* (state carried
                 unchanged, zero energy) rather than aggregating a
                 degenerate all-padding cluster.
straggler        per-member per-round Bernoulli slow-down; any straggling
                 member multiplies the cluster's round duration by
                 ``straggler_factor`` (the straggler gates the cluster —
                 the same min-frequency semantics as Alg. 2).
twin spike       per-member per-round amplification of the digital-twin
                 mapping deviation f̂ by ``twin_spike_scale`` — inflating
                 the Eqn-4 deviation term the trust rule divides by, which
                 is precisely the deviation signal trust aggregation is
                 supposed to absorb.
update corruption Byzantine corruption of the per-member parameter
                 *updates* before aggregation, on a fixed ``corrupt_frac``
                 subset of devices (drawn once from ``seed``):
                 ``sign_flip`` negates the update, ``gaussian`` adds
                 N(0, corrupt_scale²) noise, ``scaled_norm`` multiplies it
                 by ``corrupt_scale``.
input poisoning  additive Gaussian input corruption (scale
                 ``poison_scale``) on a fixed ``poison_frac`` subset of
                 devices — the attack surface for unsupervised tasks
                 (``autoencoder-anomaly``), where label flips are a no-op
                 and trust must catch the poisoned reconstruction
                 gradients instead.

``seed`` drives both the static device subsets (corrupt/poison membership)
and the per-round fault randomness stream, decoupled from the federation's
``spec.seed`` so fault realizations can be varied against a fixed
federation.
"""
from __future__ import annotations

import dataclasses

CORRUPT_MODES = ("none", "sign_flip", "gaussian", "scaled_norm")


@dataclasses.dataclass
class FaultSpec:
    """Declarative fault model (see module docstring for semantics)."""
    dropout: float = 0.0             # P(member misses a round)
    straggler_frac: float = 0.0      # P(member straggles in a round)
    straggler_factor: float = 4.0    # round-duration multiplier if any do
    twin_spike_prob: float = 0.0     # P(member's twin deviation spikes)
    twin_spike_scale: float = 8.0    # f̂ amplification for spiked members
    corrupt_mode: str = "none"          # sign_flip = -scale * upd       # sign_flip | gaussian | scaled_norm
    corrupt_frac: float = 0.0        # fraction of devices corrupting updates
    corrupt_scale: float = 4.0       # gaussian sigma / norm multiplier
    poison_frac: float = 0.0         # fraction of devices with poisoned x
    poison_scale: float = 3.0        # additive input-noise magnitude
    seed: int = 0                    # fault stream + subset-selection seed

    # ------------------------------------------------------------------ #
    @property
    def may_drop(self) -> bool:
        return self.dropout > 0.0

    @property
    def may_straggle(self) -> bool:
        return self.straggler_frac > 0.0

    @property
    def may_spike(self) -> bool:
        return self.twin_spike_prob > 0.0

    @property
    def may_corrupt(self) -> bool:
        return self.corrupt_mode != "none" and self.corrupt_frac > 0.0

    @property
    def may_poison(self) -> bool:
        return self.poison_frac > 0.0

    @property
    def active(self) -> bool:
        """Whether any fault family is enabled.  Inert specs compile the
        exact pre-fault round (identical program, identical RNG stream)."""
        return (self.may_drop or self.may_straggle or self.may_spike
                or self.may_corrupt or self.may_poison)

    # ------------------------------------------------------------------ #
    def validate(self) -> "FaultSpec":
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"faults: unknown corrupt_mode {self.corrupt_mode!r}; "
                f"valid: {list(CORRUPT_MODES)}")
        for name in ("dropout", "straggler_frac", "twin_spike_prob",
                     "corrupt_frac", "poison_frac"):
            v = getattr(self, name)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(
                    f"faults: {name}={v} must be a probability in [0, 1]")
        for name in ("straggler_factor", "twin_spike_scale",
                     "corrupt_scale", "poison_scale"):
            if float(getattr(self, name)) < 0.0:
                raise ValueError(
                    f"faults: {name}={getattr(self, name)} must be >= 0")
        return self
