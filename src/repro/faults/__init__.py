"""repro.faults — declarative, in-jit fault injection.

`FaultSpec` (on `FederationSpec.faults`) declares per-round device dropout,
straggler delay, digital-twin deviation spikes, Byzantine update
corruption, and input poisoning as data; `FaultModel` compiles it into
pure-jnp transformations the device engine applies *inside* the fused
round — one fault program for the event-heap, scanned, and mesh-sharded
execution paths.  The default spec is inert: the engine compiles the exact
pre-fault round, bit for bit.
"""
from .model import FaultModel
from .spec import CORRUPT_MODES, FaultSpec

__all__ = ["FaultSpec", "FaultModel", "CORRUPT_MODES"]
