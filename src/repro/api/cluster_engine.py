"""Cluster-major fleets: the explicit `jax.shard_map` execution engine.

`ClusterMajorEngine` re-indexes the fleet **cluster-major** at build time:
a static device permutation lays every cluster's members (plus padding
slots) out as one contiguous, shard-aligned range of every fleet-axis
`FleetState` leaf.  Slot ``c*S + j`` holds device ``member_table[c, j]``
(ascending original ids; the sentinel ``n`` marks padding), so the
membership gathers that force GSPMD to all-gather across shards under
k-means assignments become plain `dynamic_slice`s at ``c*S`` — shard-local
by construction.

The round is then an explicit `shard_map` over one mesh axis instead of a
jit the SPMD partitioner carves up:

  * replicated pre-work — RNG splits, the Alg.-2 tolerance bound — runs on
    every shard from replicated scalars (bit-identical math, no traffic);
  * the owning shard runs the *parent's* member round (batch gather, local
    SGD, Eqns 4-5 trust, Eqn-6 aggregation, energy) under a `lax.cond`,
    reading its member block with `dynamic_slice`; non-owners skip;
  * exactly **two** collectives cross shards per round: one `psum` of a
    packed scalar/metrics vector (consumed energy, round loss, the drop
    flag, the straggle factor, the Eqn-19 normalizer, the per-cluster
    frequency table, channel one-hot counts) and one `psum` of the
    Eqn-19 staleness-weighted partial sums of the cluster-parameter stack.
    The HLO test pins this: zero ``all-gather``s, at most two
    ``all-reduce``s in the compiled round.

A stable inverse permutation (``slot_of_orig``) keeps the public surface
in original device ids: `resumable_state` / `restore_resumable` speak the
unsharded checkpoint layout (checkpoints are interchangeable across
engines), the legacy ``rep``/``twins``/``channel`` views un-permute, and
fault/malicious tables are gathered by original id inside the round so
`FaultSpec` subsets mean the same devices on every engine.

Arbitrary ``(n_devices, n_clusters)`` run on any 1-D mesh: the cluster
axis pads to ``ceil(C/G)*G`` with masked sentinel clusters (event time
+inf, Eqn-19 weight 0) and the fleet axis pads to ``C_pad * S`` sentinel
slots; the padding applied is logged at build.

Exactness contract (asserted by tests/test_cluster_engine.py): on a
1-shard mesh the trace is **bit-identical** to the unsharded engine for
all three controllers on both execution paths (with the jnp aggregation
path, ``use_kernel=False``).  Across G>1 shards, scheduling, actions,
counters, energies and the frequency table stay exact (single-contributor
psums add zeros; integer counts are exact); only the Eqn-19 sums
reassociate, so losses match to rtol ~1e-5.

Two deliberate replications keep the collective count at two: the Markov
channel draws the full-fleet categorical on every shard (the transition
matrix is state-independent — identical rows — so all shards compute the
*parent's* original-order draw and gather their slots; builds reject
custom matrices that break this), and the controller features/psum ride
the same owner-gated pattern with one extra psum on the *event* path only
(the scanned path fuses it into the round's program).
"""
from __future__ import annotations

import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.control import policy as ctl_policy
from repro.control import queue as ctl_queue
from repro.core.clustering import tolerance_bound
from repro.core.energy import round_energy
from repro.core.envs import OBS_DIM
from repro.core.trust import (belief, gradient_diversity, learning_quality,
                              trust_weights, trust_weighted_average,
                              update_reputation)
from repro.core.twin import TwinState, calibrate, calibrated_freq
from repro.data.federated import sample_member_batch

from .components import WeightedAggregator
from .engine import DeviceScaleEngine, FleetState, _flatten_params
from .placement import shard_map_placement
from .spec import ShardingSpec

log = logging.getLogger("repro.cluster")

_STALE_BASE = jnp.e / 2         # Eqn-19 decay base (trust.staleness_weights)
_EPS = 1e-8                     # its normalizer epsilon
# FleetState fields sharded over the mesh axis; the rest replicate
_SHARDED_FIELDS = ("twins", "rep", "channel", "cluster_params", "cluster_ts")
# neutral member-view fills (twin.member_view): sentinel/dropped slots must
# read exactly what the parent's gather-with-fill produces, not whatever the
# padding slot carries (e.g. its alpha tally, which drifts +1 per round)
_TWIN_FILLS = TwinState(loss=0.0, freq=1.0, freq_dev=0.0, dev_estimate=0.0,
                        energy=0.0, data_size=1.0, alpha=1.0, beta=0.0,
                        router_entropy=0.0)


class ClusterMajorEngine(DeviceScaleEngine):
    """`DeviceScaleEngine` on a cluster-major layout + explicit shard_map.

    Selected by ``ShardingSpec.impl='shard_map'`` (the default for 1-D
    meshes) through ``DeviceScaleEngine.from_spec``; the jit-sharded GSPMD
    path stays registry-selectable as ``impl='gspmd'`` / the
    ``'device-gspmd'`` scale.
    """

    def __init__(self, spec, data, parts, *, controller, aggregator, task,
                 fused=None, assign=None):
        if fused is False:
            raise ValueError(
                "the cluster-major shard_map engine is fused-only "
                "(fused=False runs the eager reference round); use "
                "impl='gspmd' or an unsharded spec for the reference path")
        if not bool(getattr(aggregator, "supports_mask", False)):
            raise ValueError(
                f"aggregator {type(aggregator).__name__} has "
                "supports_mask=False (exact-shape compiles); the "
                "cluster-major engine runs the padded fixed-shape round "
                "only — pick a mask-aware rule or impl='gspmd'")
        # build the exact unsharded engine first (same RNG stream, same
        # k-means/membership/malicious tables), then permute + commit
        base = dataclasses.replace(spec, sharding=ShardingSpec())
        super().__init__(base, data, parts, controller=controller,
                         aggregator=aggregator, task=task, fused=True,
                         assign=assign)
        self.spec = spec
        n = spec.fleet.n_devices
        C = spec.clustering.n_clusters
        spec.sharding.validate(n, C)
        self.placement = shard_map_placement(spec.sharding)
        self._ax = spec.sharding.resolved_axes()[0]
        G = int(spec.sharding.mesh[0])
        S = int(self._member_table.shape[1])
        C_pad = -(-C // G) * G          # auto-pad: masked sentinel clusters
        n_pad = C_pad * S               # ... and sentinel device slots
        self._n, self._C, self._S, self._G = n, C, S, G
        self._C_pad, self._C_loc, self._n_pad = C_pad, C_pad // G, n_pad

        # the identical-rows channel trick (module docstring) needs a
        # state-independent transition matrix
        trans = np.asarray(self._trans)
        if not (trans == trans[0]).all():
            raise ValueError(
                "cluster-major engine: the channel transition matrix must "
                "be state-independent (identical rows) so every shard can "
                "reproduce the original-order channel draw; got distinct "
                "rows — use impl='gspmd'")

        # slot -> original device id (sentinel n at padding) and its
        # stable inverse; member_table rows are ascending original ids
        oos = np.full((n_pad,), n, np.int32)
        oos[:C * S] = np.asarray(self._member_table).reshape(-1)
        real = oos < n
        soo = np.zeros((n,), np.int32)
        soo[oos[real]] = np.nonzero(real)[0].astype(np.int32)
        self._oos = jnp.asarray(oos)
        self._slot_of_orig = jnp.asarray(soo)
        if C_pad != C or n_pad != n:
            log.info(
                "cluster-major padding: %d clusters -> %d and %d devices "
                "-> %d slots (mesh %s, %d member slots per cluster); "
                "sentinel clusters carry event time +inf and Eqn-19 "
                "weight 0, sentinel device slots are masked everywhere",
                C, C_pad, n, n_pad, tuple(spec.sharding.mesh), S)

        # permute the freshly built state cluster-major and commit it (and
        # the per-shard static tables) to the mesh
        self.state = self._shard_cm(self._permute_state(self.state))
        dev = NamedSharding(self.placement.mesh, P(self._ax))
        self._statics = tuple(self._commit(v, dev) for v in (
            self._oos,
            self._misbehaving_dev.at[self._oos].get(mode="fill",
                                                    fill_value=0.0),
            jnp.asarray(real),                   # slot validity (n_pad,)
            jnp.asarray(np.arange(C_pad) < C),   # cluster validity (C_pad,)
        ))
        self._scan_times = jnp.concatenate([
            jnp.zeros((C,), jnp.float32),
            jnp.full((C_pad - C,), jnp.inf, jnp.float32)])

        # Eqn-19 flatten spec: the psum'd global average travels as one
        # packed vector and unflattens to the global_params pytree
        gleaves, self._gp_def = jax.tree_util.tree_flatten(
            self.state.global_params)
        self._gp_shapes = [l.shape for l in gleaves]
        self._gp_sizes = [int(np.prod(l.shape)) if l.shape else 1
                          for l in gleaves]
        self._gp_dtypes = [l.dtype for l in gleaves]
        self._x256 = self._x[:256]

        # swap the execution paths in for the parent's jits
        self._event_fn = None
        self._round_fn = self._cm_event_round
        self._scan_cache = {}
        self._feo_fn = self._build_feats_fn()
        self._features_fn = lambda state, c: self._feo_fn(
            state, self._ftbl, self._ch3, c, *self._statics)[0]
        self._obs_fn = lambda state, c: self._feo_fn(
            state, self._ftbl, self._ch3, c, *self._statics)[1]
        self._aux_fn = self._build_aux_fn()
        # carried replicated per-round aggregates: the (C_pad,) straggler
        # frequency table and the fleet channel one-hot fractions, each
        # recomputed inside the round so the next round (and the host
        # controller ctx) reads them without touching sharded leaves
        self._ftbl, self._ch3 = self._aux_fn(self.state, *self._statics)

    # ------------------------------------------------------------------ #
    # layout plumbing
    # ------------------------------------------------------------------ #
    def _cm_pspecs(self):
        """Full-structure FleetState PartitionSpec tree (no prefix trees)."""
        dev, rep = P(self._ax), P()
        return FleetState(**{
            f: jax.tree.map(
                lambda _, s=(dev if f in _SHARDED_FIELDS else rep): s,
                getattr(self.state, f))
            for f in FleetState._fields})

    @staticmethod
    def _commit(x, sh):
        """Commit one leaf to a NamedSharding; multi-process safe.

        Under `jax.distributed` the mesh spans processes, where
        `jax.device_put` refuses non-addressable shardings — every
        process holds the identical host value (same seeds, same
        program), so assembling the global array from per-process local
        shards is exact.  Typed PRNG keys detour through key_data (the
        callback path wants a plain dtype)."""
        if sh.is_fully_addressable:
            return jax.device_put(x, sh)
        if jnp.issubdtype(jnp.asarray(x).dtype, jax.dtypes.prng_key):
            data = ClusterMajorEngine._commit(jax.random.key_data(x), sh)
            return jax.random.wrap_key_data(data)
        arr = np.asarray(jax.device_get(x))
        return jax.make_array_from_callback(arr.shape, sh,
                                            lambda idx: arr[idx])

    def _shard_cm(self, state):
        mesh = self.placement.mesh
        dev = NamedSharding(mesh, P(self._ax))
        rep = NamedSharding(mesh, P())
        sh = FleetState(**{
            f: jax.tree.map(
                lambda _, s=(dev if f in _SHARDED_FIELDS else rep): s,
                getattr(state, f))
            for f in FleetState._fields})
        return jax.tree.map(self._commit, state, sh)

    def _permute_state(self, fleet: FleetState) -> FleetState:
        """Original-order (n, C) state -> cluster-major (n_pad, C_pad)."""
        oos = self._oos

        def perm(x, fill):
            return jnp.asarray(x).at[oos].get(mode="fill", fill_value=fill)

        tw = TwinState(*[perm(getattr(fleet.twins, f),
                              getattr(_TWIN_FILLS, f))
                         for f in TwinState._fields])
        padc = self._C_pad - self._C

        def pad_c(l):
            l = jnp.asarray(l)
            if not padc:
                return l
            return jnp.concatenate(
                [l, jnp.zeros((padc,) + l.shape[1:], l.dtype)], axis=0)

        return FleetState(
            twins=tw, rep=perm(fleet.rep, 1.0),
            channel=perm(jnp.asarray(fleet.channel, jnp.int32), 0),
            cluster_params=jax.tree.map(pad_c, fleet.cluster_params),
            global_params=fleet.global_params,
            cluster_ts=pad_c(jnp.asarray(fleet.cluster_ts, jnp.float32)),
            queue=fleet.queue, round=fleet.round, key=fleet.key)

    # ------------------------------------------------------------------ #
    # shard-local building blocks
    # ------------------------------------------------------------------ #
    def _local_freq_table(self, twins, mskslot_l):
        """This shard's (C_loc,) straggler frequency table — bit-equal per
        row to the parent's `_cluster_freq_table` (min is order-free)."""
        f = calibrated_freq(twins).reshape(self._C_loc, self._S)
        m = mskslot_l.reshape(self._C_loc, self._S)
        fmin = jnp.min(jnp.where(m, f, jnp.inf), axis=1)
        return jnp.where(m.any(axis=1), fmin, 1.0)

    def _row_scatter(self, full, vals, maskd, lo, mine):
        """Masked (S,)-row scatter at slot ``lo``, applied only on the
        owning shard — the slot-space twin of ``.at[members].set(mode=
        'drop')``."""
        old = jax.lax.dynamic_slice(full, (lo,), (self._S,))
        new = jnp.where(maskd, vals.astype(full.dtype), old)
        upd = jax.lax.dynamic_update_slice(full, new, (lo,))
        return jnp.where(mine, upd, full)

    def _agg_call(self, new, w, mask):
        """Eqn-6 aggregation inside the shard program.  Weighted rules run
        the pure-jnp oracle (`trust_weighted_average`) — identical math to
        their ``use_kernel=False`` path — instead of dispatching a Pallas
        kernel from inside shard_map; masked robust rules are jnp already."""
        ag = self.aggregator
        if isinstance(ag, WeightedAggregator):
            w2 = ag._effective_weights(w, mask)
            w2 = w2 * mask.astype(w2.dtype)
            return trust_weighted_average(new, w2)
        return ag(new, w, mask)

    # ------------------------------------------------------------------ #
    # the per-shard round (traced under shard_map)
    # ------------------------------------------------------------------ #
    def _cm_round_local(self, state, ftbl, ch3, c, a_raw,
                        oos_l, misb_l, mskslot_l, validc_l):
        """One cluster round, shard-local: the parent `_fleet_round` split
        into replicated pre-work, an owner-gated member phase, and two
        psums.  Returns (state', ftbl', ch3', metrics)."""
        del ch3                         # consumed by the caller's next obs
        spec = self.spec
        task = self.task
        fm = self.faults
        S, C_loc = self._S, self._C_loc
        ax = self._ax
        g = jax.lax.axis_index(ax)
        cl = jnp.clip(c - g * C_loc, 0, C_loc - 1)   # local cluster row
        lo = cl * S                                   # local slot offset
        mine = (c >= g * C_loc) & (c < (g + 1) * C_loc)

        # --- replicated pre-work: exact parent RNG stream + Alg.-2 bound
        if fm.active:
            key, kb, ke, kc2, kdp, kflt = jax.random.split(state.key, 6)
        else:
            key, kb, ke, kc2, kdp = jax.random.split(state.key, 5)
            kflt = None
        a_req = jnp.clip(jnp.asarray(a_raw), 1, self._n_actions)
        # max over the *real* clusters only (sentinel table rows hold 1.0)
        t_ref = a_req.astype(jnp.float32) / jnp.maximum(
            jnp.max(ftbl[:self._C]), 1e-6)
        alpha = jnp.minimum(
            1.0, spec.clustering.alpha0 +
            spec.clustering.alpha_growth * state.round.astype(jnp.float32))
        a = tolerance_bound(a_req, ftbl[c], t_ref, alpha)
        a = jnp.clip(a, 1, self._n_actions)

        def tslice(leaf, fill, mask):
            sl = jax.lax.dynamic_slice(leaf, (lo,), (S,))
            return jnp.where(mask, sl, fill)

        # --- owner phase: the parent's member round, verbatim math.  The
        # full-fleet static tables (member/partition/data/fault) ride in as
        # replicated closure constants, so gathers by *original* id are
        # identical to the parent's; only sharded FleetState leaves read
        # through dynamic_slice at the cluster's slot block.
        def owner(_):
            members = self._member_table[c]
            mask = self._member_mask[c]
            if fm.may_drop:
                mask = fm.drop_mask(kflt, mask, members)
                members = jnp.where(mask, members, self._sentinel)
            mask_f = mask.astype(jnp.float32)
            cnt = jnp.maximum(jnp.sum(mask_f), 1.0)

            sel = sample_member_batch(kb, self._part_idx, self._part_len,
                                      members, spec.local_batch)
            x = self._x[sel]
            y = self._y[sel]
            if fm.may_poison:
                x = fm.poison_inputs(kflt, x, members)
            mal_m = self._malicious_dev.at[members].get(mode="fill",
                                                        fill_value=0.0)
            y = jnp.where(mal_m[:, None] > 0.5, task.corrupt_labels(y), y)
            batch = {"x": x, "y": y}

            cur_row = jax.tree.map(
                lambda l: jax.lax.dynamic_index_in_dim(l, cl, 0,
                                                       keepdims=False),
                state.cluster_params)
            stacked = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (S,) + l.shape),
                cur_row)
            new = task.local_train(stacked, batch, spec.lr, a)
            if fm.may_corrupt:
                new = fm.corrupt_updates(kflt, new, stacked, members)

            upd_flat = _flatten_params(new) - _flatten_params(stacked)
            q = learning_quality(upd_flat, mask)
            div = gradient_diversity(upd_flat, mask)
            tw_m = TwinState(*[
                tslice(getattr(state.twins, f), getattr(_TWIN_FILLS, f),
                       mask) for f in TwinState._fields])
            if fm.may_spike:
                tw_m = fm.spike_twins(kflt, tw_m, mask, members)
            b = belief(tw_m, q, spec.channel.pkt_fail, div)
            rep_m = update_reputation(
                tslice(state.rep, 1.0, mask), b,
                spec.channel.pkt_fail, spec.iota)
            w = trust_weights(rep_m, mask)
            if spec.privacy.clip > 0.0:
                from repro.core.privacy import dp_aggregate
                agg = dp_aggregate(
                    kdp, new, cur_row,
                    w if spec.aggregator.kind == "trust" else mask_f / cnt,
                    spec.privacy.clip, spec.privacy.noise, n_clients=cnt)
            else:
                agg = self._agg_call(new, w, mask)

            losses = task.losses(new, batch)
            true_freq = tslice(state.twins.freq + state.twins.freq_dev,
                               1.0, mask)
            ch_m = tslice(state.channel, 0, mask)
            e = round_energy(a.astype(jnp.float32), true_freq, ch_m,
                             ke, members=members) * mask_f
            # the straggle *factor* (straggle() multiplies its dur arg, so
            # dur=1 extracts it); applied post-psum as dur * factor — the
            # exact product the parent computes
            stretch = (fm.straggle(kflt, jnp.float32(1.0), mask,
                                    members)
                       if fm.may_straggle else jnp.float32(1.0))
            empty = ((jnp.sum(mask_f) < 0.5).astype(jnp.float32)
                     if fm.may_drop else jnp.float32(0.0))
            return {"agg": agg, "losses": losses, "e": e, "rep_m": rep_m,
                    "maskd": mask_f, "consumed": jnp.sum(e),
                    "loss": jnp.sum(losses * mask_f) / cnt,
                    "empty": empty, "stretch": stretch}

        def skip(_):
            zS = jnp.zeros((S,), jnp.float32)
            z = jnp.float32(0.0)
            return {"agg": jax.tree.map(jnp.zeros_like, state.global_params),
                    "losses": zS, "e": zS, "rep_m": zS, "maskd": zS,
                    "consumed": z, "loss": z, "empty": z,
                    "stretch": jnp.float32(0.0)}

        out = jax.lax.cond(mine, owner, skip, None)
        maskd = out["maskd"] > 0.5      # post-drop member validity

        # --- all-shard state updates (slot space)
        rep_new = self._row_scatter(state.rep, out["rep_m"], maskd, lo, mine)
        loss_new = self._row_scatter(state.twins.loss, out["losses"],
                                     maskd, lo, mine)
        e_row = self._row_scatter(jnp.zeros_like(state.twins.energy),
                                  out["e"], maskd, lo, mine)
        tw = state.twins._replace(
            loss=loss_new, energy=state.twins.energy + e_row,
            alpha=state.twins.alpha + (1.0 - misb_l),
            beta=state.twins.beta + misb_l)
        if spec.fleet.calibrate_dt:
            tw = calibrate(tw)

        # identical-rows channel: every shard reproduces the parent's
        # original-order full-fleet draw, then gathers its own slots
        new_ch = jax.random.categorical(
            kc2, jnp.broadcast_to(jnp.log(self._trans[0] + 1e-12),
                                  (self._n, 3)), axis=-1)
        channel_l = new_ch.at[oos_l].get(mode="fill", fill_value=0)

        rnd = state.round + 1
        rnd_f = rnd.astype(jnp.float32)

        def set_row(L, v):
            upd = jax.lax.dynamic_update_slice(
                L, v.astype(L.dtype)[None], (cl,) + (0,) * (L.ndim - 1))
            return jnp.where(mine, upd, L)

        cp1 = jax.tree.map(set_row, state.cluster_params, out["agg"])
        ts_new = jnp.where(
            mine, jax.lax.dynamic_update_slice(state.cluster_ts,
                                               rnd_f[None], (cl,)),
            state.cluster_ts)

        # --- psum #1: packed scalars + the recomputed frequency table
        # (disjoint per-shard blocks; exact) + channel one-hot counts
        # (integer-valued; exact)
        ftbl_loc = self._local_freq_table(tw, mskslot_l)
        mskslot_f = mskslot_l.astype(jnp.float32)
        w_un = _STALE_BASE ** (-(rnd_f - ts_new)) * validc_l.astype(
            jnp.float32)
        vec = jnp.concatenate([
            jnp.stack([out["consumed"], out["loss"], out["empty"],
                       out["stretch"], jnp.sum(w_un)]),
            jax.lax.dynamic_update_slice(
                jnp.zeros((self._C_pad,), jnp.float32), ftbl_loc,
                (g * C_loc,)),
            jnp.sum(jax.nn.one_hot(channel_l, 3) * mskslot_f[:, None],
                    axis=0),
        ])
        vec = jax.lax.psum(vec, ax)
        consumed = vec[0]
        loss_m = vec[1]
        empty_ps = vec[2]
        stretch_ps = vec[3]
        den = vec[4]
        ftbl_new = vec[5:5 + self._C_pad]
        ch3_new = vec[5 + self._C_pad:] / self._n

        # --- psum #2: Eqn-19 staleness-weighted global average over the
        # (sharded) cluster stack, as one packed partial-sum vector
        w_norm = w_un / (den + _EPS)
        parts = [
            jnp.sum(l * w_norm.reshape((-1,) + (1,) * (l.ndim - 1)).astype(
                l.dtype), axis=0).reshape(-1)
            for l in jax.tree_util.tree_leaves(cp1)]
        gvec = jax.lax.psum(jnp.concatenate(parts), ax)
        offs = np.cumsum([0] + self._gp_sizes)
        gleaves = [gvec[offs[i]:offs[i + 1]].reshape(
            self._gp_shapes[i]).astype(self._gp_dtypes[i])
            for i in range(len(self._gp_sizes))]
        gparams = jax.tree_util.tree_unflatten(self._gp_def, gleaves)
        cp2 = jax.tree.map(set_row, cp1, gparams)

        if fm.may_drop:
            # fully-dropped cluster: graceful skip, exactly as the parent
            empty_b = empty_ps > 0.5
            revert = lambda old, newv: jax.tree.map(
                lambda o, v: jnp.where(empty_b, o, v), old, newv)
            consumed = jnp.where(empty_b, 0.0, consumed)
            tw = revert(state.twins, tw)
            rep_new = revert(state.rep, rep_new)
            cp2 = revert(state.cluster_params, cp2)
            gparams = revert(state.global_params, gparams)
            ts_new = revert(state.cluster_ts, ts_new)
            ftbl_new = jnp.where(empty_b, ftbl, ftbl_new)

        queue = ctl_queue.advance(state.queue, consumed,
                                  self._queue_per_slot)
        dur = a.astype(jnp.float32) / jnp.maximum(ftbl_new[c], 1e-6)
        if fm.may_straggle:
            dur = dur * stretch_ps

        new_state = FleetState(
            twins=tw, rep=rep_new, channel=channel_l, cluster_params=cp2,
            global_params=gparams, cluster_ts=ts_new, queue=queue,
            round=rnd, key=key)
        metrics = {"a": a, "dur": dur, "consumed": consumed,
                   "loss": loss_m}
        return new_state, ftbl_new, ch3_new, metrics

    # ------------------------------------------------------------------ #
    # controller features / observation, shard-local
    # ------------------------------------------------------------------ #
    def _cm_feats_local(self, state, ftbl, ch3, c, mskslot_l, needs_obs):
        """Parent `_ctl_features` + `_scan_obs` over the owner's slot
        block; one (4,) psum replicates the scalars (+zeros: exact)."""
        S, C_loc = self._S, self._C_loc
        g = jax.lax.axis_index(self._ax)
        cl = jnp.clip(c - g * C_loc, 0, C_loc - 1)
        lo = cl * S
        mine = (c >= g * C_loc) & (c < (g + 1) * C_loc)
        tw = state.twins

        def owner(_):
            mask = jax.lax.dynamic_slice(mskslot_l, (lo,), (S,))
            mask_f = mask.astype(jnp.float32)
            cnt = jnp.maximum(jnp.sum(mask_f), 1.0)
            loss_s = jax.lax.dynamic_slice(tw.loss, (lo,), (S,))
            loss = jnp.sum(jnp.where(mask, loss_s, 0.0)) / cnt
            loss = jnp.nan_to_num(loss, nan=0.0, posinf=2.3)
            f_s = jax.lax.dynamic_slice(calibrated_freq(tw), (lo,), (S,))
            mean_freq = jnp.sum(jnp.where(mask, f_s, 0.0)) / cnt
            ch_s = jax.lax.dynamic_slice(state.channel, (lo,), (S,))
            good = jnp.sum(jnp.where(
                mask, (ch_s == 0).astype(jnp.float32), 0.0)) / cnt
            if needs_obs:
                row = jax.tree.map(
                    lambda l: jax.lax.dynamic_index_in_dim(
                        l, cl, 0, keepdims=False), state.cluster_params)
                tau = self.task.hidden_mean(row, self._x256)
            else:
                tau = jnp.float32(0.0)
            return jnp.stack([loss, mean_freq, good, tau])

        vec = jax.lax.psum(
            jax.lax.cond(mine, owner,
                         lambda _: jnp.zeros((4,), jnp.float32), None),
            self._ax)
        feats = {"cluster_loss": vec[0], "mean_freq": vec[1],
                 "channel_good_frac": vec[2], "cluster_freq": ftbl[c]}
        if needs_obs:
            obs48 = ctl_policy.deploy_obs(
                vec[0], state.queue,
                state.round.astype(jnp.float32) / 100.0, vec[3],
                state.round % 10, ch3, vec[1])
        else:
            obs48 = jnp.zeros((OBS_DIM,), jnp.float32)
        return feats, obs48

    # ------------------------------------------------------------------ #
    # compiled entry points
    # ------------------------------------------------------------------ #
    def _build_event_fn(self):
        pspecs = self._cm_pspecs()
        dev = P(self._ax)
        m_specs = {"a": P(), "dur": P(), "consumed": P(), "loss": P()}
        sm = shard_map(
            self._cm_round_local, mesh=self.placement.mesh,
            in_specs=(pspecs, P(), P(), P(), P(), dev, dev, dev, dev),
            out_specs=(pspecs, P(), P(), m_specs),
            check_rep=False)
        return jax.jit(sm)

    def _cm_event_round(self, state, c, a_raw, members=None, mask=None):
        """Event-path round: `_round_fn`-compatible host wrapper (the
        members/mask args of the parent's signature are unused — the
        layout *is* the membership)."""
        del members, mask
        if self._event_fn is None:
            self._event_fn = self._build_event_fn()
        state, self._ftbl, self._ch3, m = self._event_fn(
            state, self._ftbl, self._ch3, jnp.int32(c),
            jnp.asarray(a_raw, jnp.int32), *self._statics)
        return state, m

    def _build_feats_fn(self):
        pspecs = self._cm_pspecs()
        dev = P(self._ax)

        def fn(state, ftbl, ch3, c, oos_l, misb_l, mskslot_l, validc_l):
            del oos_l, misb_l, validc_l
            return self._cm_feats_local(state, ftbl, ch3, c, mskslot_l,
                                        True)

        f_specs = {"cluster_loss": P(), "mean_freq": P(),
                   "channel_good_frac": P(), "cluster_freq": P()}
        sm = shard_map(
            fn, mesh=self.placement.mesh,
            in_specs=(pspecs, P(), P(), P(), dev, dev, dev, dev),
            out_specs=(f_specs, P()), check_rep=False)
        return jax.jit(sm)

    def _build_aux_fn(self):
        """(ftbl, ch3) from a freshly committed state — used at build and
        after `restore_resumable` (both are round-start equivalents)."""
        pspecs = self._cm_pspecs()
        dev = P(self._ax)
        C_pad, C_loc, n = self._C_pad, self._C_loc, self._n
        ax = self._ax

        def aux(state, oos_l, misb_l, mskslot_l, validc_l):
            del oos_l, misb_l, validc_l
            g = jax.lax.axis_index(ax)
            f_loc = self._local_freq_table(state.twins, mskslot_l)
            msk_f = mskslot_l.astype(jnp.float32)
            vec = jnp.concatenate([
                jax.lax.dynamic_update_slice(
                    jnp.zeros((C_pad,), jnp.float32), f_loc, (g * C_loc,)),
                jnp.sum(jax.nn.one_hot(state.channel, 3) * msk_f[:, None],
                        axis=0)])
            vec = jax.lax.psum(vec, ax)
            return vec[:C_pad], vec[C_pad:] / n

        sm = shard_map(aux, mesh=self.placement.mesh,
                       in_specs=(pspecs, dev, dev, dev, dev),
                       out_specs=(P(), P()), check_rep=False)
        return jax.jit(sm)

    # ------------------------------------------------------------------ #
    # scanned execution: the whole K-round scan inside ONE shard_map
    # ------------------------------------------------------------------ #
    def _build_scan_fn(self, K: int, pol: ctl_policy.ScanPolicy):
        pspecs = self._cm_pspecs()
        dev = P(self._ax)
        ctl_spec = jax.tree.map(lambda _: P(), pol.state)

        def local(state, times, ctl, energy, ftbl, ch3,
                  oos_l, misb_l, mskslot_l, validc_l):
            def body(carry, _):
                state, times, ctl, energy, ftbl, ch3 = carry
                c = jnp.argmin(times).astype(jnp.int32)
                t = times[c]
                feats, obs48 = self._cm_feats_local(
                    state, ftbl, ch3, c, mskslot_l, pol.needs_obs)
                cobs = ctl_policy.CtlObs(
                    round=state.round, cluster=c, queue=state.queue,
                    cluster_loss=feats["cluster_loss"],
                    cluster_freq=feats["cluster_freq"],
                    mean_freq=feats["mean_freq"],
                    channel_good_frac=feats["channel_good_frac"],
                    energy_used=energy, dqn_obs=obs48)
                a_raw, ctl = pol.step(ctl, cobs)
                state, ftbl, ch3, m = self._cm_round_local(
                    state, ftbl, ch3, c, a_raw,
                    oos_l, misb_l, mskslot_l, validc_l)
                times = times.at[c].set(t + m["dur"])
                energy = energy + m["consumed"]
                ys = {"t": t, "cluster": c, "a": m["a"], "dur": m["dur"],
                      "consumed": m["consumed"], "loss": m["loss"]}
                return (state, times, ctl, energy, ftbl, ch3), ys

            return jax.lax.scan(body, (state, times, ctl, energy, ftbl,
                                       ch3), None, length=K)

        ys_specs = {k: P() for k in ("t", "cluster", "a", "dur",
                                     "consumed", "loss")}
        sm = shard_map(
            local, mesh=self.placement.mesh,
            in_specs=(pspecs, P(), ctl_spec, P(), P(), P(),
                      dev, dev, dev, dev),
            out_specs=((pspecs, P(), ctl_spec, P(), P(), P()), ys_specs),
            check_rep=False)
        return jax.jit(sm)

    def run_scanned(self, K: int, *, eval_final: bool = True):
        scan_policy = getattr(self.controller, "scan_policy", None)
        if scan_policy is None:
            raise ValueError(
                f"controller {type(self.controller).__name__} has no "
                "scan_policy(); use the event-heap run() instead")
        pol = scan_policy()
        K = int(K)
        args = (self.state, self._scan_times, pol.state,
                self._scan_energy_start(), self._ftbl, self._ch3,
                *self._statics)
        fn = self._scan_cache.get(K)
        if fn is None:
            fn = self._instrument_compile(
                f"cm_run_scanned[K={K}]", self._build_scan_fn(K, pol),
                args)
            self._scan_cache[K] = fn
        if self.obs is None:
            out = fn(*args)
        else:
            with self.obs.span("round", mode="scanned", rounds=K) as sp:
                out = fn(*args)
                sp.mark("dispatch")
                jax.block_until_ready(out)
        (state, times, _, energy_end, ftbl, ch3), ys = out
        self.state = state
        self._scan_times = times
        self._ftbl, self._ch3 = ftbl, ch3
        return self._emit_scanned_trace(ys, K, eval_final, energy_end)

    # ------------------------------------------------------------------ #
    # checkpoints + legacy views: original device order at the boundary
    # ------------------------------------------------------------------ #
    def resumable_state(self) -> dict:
        """Unsharded layout (original device order, real clusters only) —
        interchangeable with `DeviceScaleEngine` checkpoints in both
        directions."""
        self._flush_pending()
        soo = self._slot_of_orig
        st = self.state
        fleet = FleetState(
            twins=jax.tree.map(lambda l: l[soo], st.twins),
            rep=st.rep[soo], channel=st.channel[soo],
            cluster_params=jax.tree.map(lambda l: l[:self._C],
                                        st.cluster_params),
            global_params=st.global_params,
            cluster_ts=st.cluster_ts[:self._C],
            queue=st.queue, round=st.round, key=st.key)
        return {"fleet": fleet, "times": self._scan_times[:self._C]}

    def restore_resumable(self, tree: dict, *, rounds: int,
                          energy: float) -> None:
        fleet = tree["fleet"]
        if not isinstance(fleet, FleetState):
            fleet = FleetState(*fleet) if isinstance(fleet, (tuple, list)) \
                else FleetState(**fleet)
        self.state = self._shard_cm(self._permute_state(fleet))
        self._scan_times = jnp.concatenate([
            jnp.asarray(tree["times"], jnp.float32),
            jnp.full((self._C_pad - self._C,), jnp.inf, jnp.float32)])
        self._rounds = int(rounds)
        self._energy_used = float(energy)
        self._pending = []
        self._energy_dev = jnp.float32(energy)
        self._ftbl, self._ch3 = self._aux_fn(self.state, *self._statics)
        sync_queue = getattr(self.controller, "sync_queue", None)
        if sync_queue is not None:
            sync_queue(self.state.queue)

    def obs_state_summary(self) -> dict:
        """Telemetry state summary, masked to real device slots: sentinel
        slots (cluster-major padding) carry the `_TWIN_FILLS` values and
        would skew the reputation stats if reduced over naively."""
        if self._obs_summary_fn is None:
            def summarize(state, valid):
                rep = state.rep
                v = valid.astype(jnp.float32)
                nv = jnp.sum(v)
                return {
                    "queue_deficit": state.queue,
                    "reputation_min": jnp.min(
                        jnp.where(valid, rep, jnp.inf)),
                    "reputation_mean": jnp.sum(rep * v) / nv,
                    "reputation_max": jnp.max(
                        jnp.where(valid, rep, -jnp.inf)),
                    "twin_beta_sum": jnp.sum(state.twins.beta * v)}
            self._obs_summary_fn = jax.jit(summarize)
        out = jax.device_get(self._obs_summary_fn(
            self.state, self._statics[2]))
        return {k: float(v) for k, v in out.items()}

    @property
    def scan_times(self):
        return self._scan_times[:self._C]

    @property
    def rep(self):
        return self.state.rep[self._slot_of_orig]

    @property
    def twins(self):
        return jax.tree.map(lambda l: l[self._slot_of_orig],
                            self.state.twins)

    @property
    def channel(self):
        return self.state.channel[self._slot_of_orig]
