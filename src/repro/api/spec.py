"""`FederationSpec`: the declarative description of a federation experiment.

One dataclass tree covers both scales of the system — the device-scale
discrete-event simulator (paper §IV-D) and the datacenter-scale sharded
`fl_step` modes — so a scenario is data, not code.  `to_dict`/`from_dict`
round-trip the tree through plain JSON-able dicts for config files;
`from_dict` rejects unknown keys and `validate` rejects unknown component
names against the registries.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.faults.spec import FaultSpec

from . import registry

DEVICE_SCALE = "device"          # discrete-event simulator over the MLP task
DATACENTER_SCALE = "datacenter"  # sharded fl_step modes over the LM task

# default axis names by mesh rank: 1-D meshes shard the fleet's device dim;
# 2-D meshes put the cluster stack on the leading axis
_DEFAULT_AXES = {1: ("fleet",), 2: ("cluster", "fleet")}

# sharded execution implementations (`ShardingSpec.impl`)
SHARD_MAP_IMPL = "shard_map"    # explicit per-shard round, cluster-major
GSPMD_IMPL = "gspmd"            # jit in/out_shardings, inferred collectives


@dataclasses.dataclass
class ShardingSpec:
    """Where the federation runs, as spec data (resolved by
    `repro.api.placement` into a `jax.sharding` mesh + per-leaf-group
    `NamedSharding`s).

    ``mesh`` is the mesh shape; ``()`` (the default) is the single-device
    fallback — bit-identical to the pre-placement engine.  ``axes`` names
    one mesh axis per entry (defaults: 1-D ``("fleet",)``, 2-D
    ``("cluster", "fleet")``).  ``device_axis`` shards the `FleetState`
    device-dim leaf group (twins / rep / channel) and ``cluster_axis`` the
    cluster-dim group (stacked params / event times); either may be None to
    replicate that group.  Scalars (queue, round, RNG key) and the global
    model are always replicated.

    ``impl`` picks the sharded execution implementation:

      "shard_map"   the cluster-major engine: the fleet is re-indexed so
                    each cluster's member slots are contiguous, every
                    FleetState leaf co-shards over one mesh axis, and the
                    round is an explicit `jax.shard_map` whose only
                    collectives are one psum for metrics and one for the
                    Eqn-19 global average.  1-D meshes only.  Arbitrary
                    (n_devices, n_clusters) run on any shard count — the
                    engine pads with masked sentinel devices/clusters.
      "gspmd"       the PR-5 path: leaf-group NamedShardings + jit
                    in/out_shardings, collectives inferred by the SPMD
                    partitioner.  Requires exact mesh divisibility.
      None          (default) "shard_map" for 1-D meshes, "gspmd" for 2-D.
    """
    mesh: Tuple[int, ...] = ()
    axes: Optional[Tuple[str, ...]] = None
    device_axis: Optional[str] = "fleet"
    cluster_axis: Optional[str] = None
    impl: Optional[str] = None

    def __post_init__(self):
        # JSON round-trips deliver lists; normalize so eq/hash behave
        self.mesh = tuple(int(m) for m in self.mesh)
        if self.axes is not None:
            self.axes = tuple(str(a) for a in self.axes)
        if self.impl is not None:
            self.impl = str(self.impl)

    @property
    def is_sharded(self) -> bool:
        return bool(self.mesh)

    def resolved_impl(self) -> Optional[str]:
        """The sharded implementation this spec runs on (None: unsharded)."""
        if not self.mesh:
            return None
        if self.impl is not None:
            if self.impl not in (SHARD_MAP_IMPL, GSPMD_IMPL):
                raise ValueError(
                    f"sharding: unknown impl {self.impl!r}; valid: "
                    f"{SHARD_MAP_IMPL!r}, {GSPMD_IMPL!r}")
            return self.impl
        return SHARD_MAP_IMPL if len(self.mesh) == 1 else GSPMD_IMPL

    def resolved_axes(self) -> Tuple[str, ...]:
        if self.axes is not None:
            return self.axes
        try:
            return _DEFAULT_AXES[len(self.mesh)]
        except KeyError:
            raise ValueError(
                f"sharding: no default axis names for a {len(self.mesh)}-D "
                "mesh; set axes=(...) explicitly") from None

    def resolved_cluster_axis(self, axes: Tuple[str, ...]) -> Optional[str]:
        """Default cluster placement: the "cluster" axis when the mesh has
        one, else replicated."""
        if self.cluster_axis is not None:
            return self.cluster_axis
        return "cluster" if "cluster" in axes else None

    def validate(self, n_devices: int, n_clusters: int) -> "ShardingSpec":
        if not self.mesh:
            return self
        if any(m < 1 for m in self.mesh):
            raise ValueError(f"sharding: mesh {self.mesh} has a "
                             "non-positive extent")
        axes = self.resolved_axes()
        if len(axes) != len(self.mesh):
            raise ValueError(
                f"sharding: mesh {self.mesh} has {len(self.mesh)} axes but "
                f"axes={axes} names {len(axes)}")
        if len(set(axes)) != len(axes):
            raise ValueError(f"sharding: duplicate axis names in {axes}")
        impl = self.resolved_impl()
        if impl == SHARD_MAP_IMPL:
            # the cluster-major shard_map engine co-shards every leaf over
            # one axis and pads indivisible fleets with masked sentinel
            # devices/clusters itself — no divisibility requirement here
            # (the engine logs the padding it applies)
            if len(self.mesh) != 1:
                raise ValueError(
                    f"sharding: impl='shard_map' runs on 1-D meshes (one "
                    f"cluster-shard axis); got mesh {self.mesh} — use "
                    "impl='gspmd' for multi-axis placements")
            if n_devices < n_clusters:
                raise ValueError("n_devices < n_clusters")
            for role, name in (("device_axis", self.device_axis),
                               ("cluster_axis", self.cluster_axis)):
                if name is not None and name not in axes:
                    raise ValueError(
                        f"sharding: {role}={name!r} is not a mesh axis; "
                        f"axes={axes}")
            return self
        cluster_axis = self.resolved_cluster_axis(axes)
        for role, name, dim, total in (
                ("device_axis", self.device_axis, "n_devices", n_devices),
                ("cluster_axis", cluster_axis, "n_clusters", n_clusters)):
            if name is None:
                continue
            if name not in axes:
                raise ValueError(
                    f"sharding: {role}={name!r} is not a mesh axis; "
                    f"axes={axes}")
            k = self.mesh[axes.index(name)]
            if total % k:
                raise ValueError(
                    f"sharding: mesh axis {name!r} has {k} shards, which "
                    f"does not divide {dim}={total}; pad the fleet or pick "
                    f"a mesh shape with {dim} % shards == 0")
        if (self.device_axis is not None and cluster_axis is not None
                and self.device_axis == cluster_axis):
            raise ValueError(
                f"sharding: device_axis and cluster_axis are both "
                f"{cluster_axis!r}; the device and cluster dims need "
                "distinct mesh axes (or replicate one with None)")
        return self


@dataclasses.dataclass
class FleetSpec:
    """The device fleet and its digital twins (Eqns 1-2)."""
    n_devices: int = 16
    malicious_frac: float = 0.0      # Byzantine label-flippers
    dt_max_dev: float = 0.2          # DT mapping error ~ U(0, max_dev)
    calibrate_dt: bool = True        # Eqn-2 self-calibration on/off


@dataclasses.dataclass
class ClusteringSpec:
    """K-means clustering + Alg.-2 tolerance bound."""
    n_clusters: int = 4
    alpha0: float = 0.5              # tolerance factor (grows with rounds)
    alpha_growth: float = 0.02


@dataclasses.dataclass
class ControllerSpec:
    """Aggregation-frequency controller: fixed | dqn | lyapunov."""
    kind: str = "dqn"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class AggregatorSpec:
    """Intra-cluster aggregation rule (Eqn 6 or a robust baseline)."""
    kind: str = "trust"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    use_kernel: bool = True          # route through the Pallas kernel


@dataclasses.dataclass
class TaskSpec:
    """Model/task adapter: mlp (device scale) | lm (datacenter scale)."""
    kind: str = "mlp"
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PrivacySpec:
    """Client-level DP on aggregated deltas; clip <= 0 disables."""
    clip: float = 0.0
    noise: float = 0.0


@dataclasses.dataclass
class ChannelSpec:
    """Markov wireless channel + packet-failure probability (Eqn 4's u)."""
    p_good: float = 0.5
    pkt_fail: float = 0.05


@dataclasses.dataclass
class FederationSpec:
    scale: str = DEVICE_SCALE
    fleet: FleetSpec = dataclasses.field(default_factory=FleetSpec)
    clustering: ClusteringSpec = dataclasses.field(
        default_factory=ClusteringSpec)
    controller: ControllerSpec = dataclasses.field(
        default_factory=ControllerSpec)
    aggregator: AggregatorSpec = dataclasses.field(
        default_factory=AggregatorSpec)
    task: TaskSpec = dataclasses.field(default_factory=TaskSpec)
    privacy: PrivacySpec = dataclasses.field(default_factory=PrivacySpec)
    channel: ChannelSpec = dataclasses.field(default_factory=ChannelSpec)
    sharding: ShardingSpec = dataclasses.field(default_factory=ShardingSpec)
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    sim_seconds: float = 60.0        # device scale: simulated wall-clock
    rounds: int = 20                 # global rounds (datacenter scale, and
                                     # the K of device-scale "scanned" runs)
    execution: str = "event"         # device scale: "event" (discrete-event
                                     # heap) | "scanned" (lax.scan over K
                                     # rounds, controller in-jit)
    local_batch: int = 64
    lr: float = 0.1
    iota: float = 0.1                # Eqn 5 uncertainty coefficient
    seed: int = 0

    # ------------------------------------------------------------------ #
    def validate(self) -> "FederationSpec":
        # `scale` is a registry key like every other component; the built-in
        # engines register themselves on import of repro.api.engine
        from . import engine as _engine  # noqa: F401  (populates ENGINES)
        registry.ENGINES.get(self.scale)
        registry.CONTROLLERS.get(self.controller.kind)
        registry.AGGREGATORS.get(self.aggregator.kind)
        registry.TASKS.get(self.task.kind)
        # built-in tasks are scale-specific; custom registrations (tasks or
        # engines) are not checked — they may support either engine protocol
        scale_of = {"mlp": DEVICE_SCALE,
                    "autoencoder-anomaly": DEVICE_SCALE,
                    "lm": DATACENTER_SCALE}
        want = scale_of.get(self.task.kind)
        if (want is not None and want != self.scale
                and self.scale in (DEVICE_SCALE, DATACENTER_SCALE)):
            fit = "lm" if self.scale == DATACENTER_SCALE else "mlp"
            raise ValueError(
                f"task {self.task.kind!r} is {want}-scale but spec has "
                f"scale={self.scale!r}; use task {fit!r}")
        # custom-registered engines may consume a placement; only the
        # built-in datacenter engine is known not to (fl_step manages its
        # own sharding)
        if self.sharding.is_sharded and self.scale == DATACENTER_SCALE:
            raise ValueError(
                "sharding: mesh placement is not supported at datacenter "
                "scale (the fl_step modes manage their own sharding)")
        self.sharding.validate(self.fleet.n_devices,
                               self.clustering.n_clusters)
        self.faults.validate()
        if self.faults.active and self.scale == DATACENTER_SCALE:
            raise ValueError(
                "faults: fault injection is device-scale only (the "
                "datacenter fl_step modes have no fault model)")
        if self.scale == DATACENTER_SCALE:
            # fl_step implements Eqn-6 trust weighting inside the jit-ed
            # step; robust rules and DP have no datacenter implementation
            # yet, so reject rather than silently run without them
            if self.aggregator.kind not in ("trust", "fedavg"):
                raise ValueError(
                    f"aggregator {self.aggregator.kind!r} is not supported "
                    "at datacenter scale (fl_step implements Eqn-6 trust "
                    "weighting only)")
            if self.privacy.clip > 0.0 or self.privacy.noise > 0.0:
                raise ValueError(
                    "privacy (DP) is not implemented at datacenter scale")
        if self.execution not in ("event", "scanned"):
            raise ValueError(f"unknown execution {self.execution!r}; "
                             "valid: 'event', 'scanned'")
        if self.execution == "scanned":
            if self.scale != DEVICE_SCALE:
                raise ValueError("execution='scanned' is device-scale only "
                                 "(the datacenter engine is already a "
                                 "fixed round loop)")
            # the scan needs the padded fused round: built-in rules without
            # a masked variant cannot join it (custom registrations are
            # checked at run_scanned time instead)
            from repro.core.robust import AGGREGATORS as _ROBUST
            from repro.core.robust import MASKED_AGGREGATORS as _MASKED
            if self.aggregator.kind in set(_ROBUST) - set(_MASKED):
                raise ValueError(
                    f"aggregator {self.aggregator.kind!r} has no masked "
                    "variant (supports_mask=False); execution='scanned' "
                    "needs the padded fused round — pick a mask-aware "
                    "rule (trust/fedavg/"
                    + "/".join(sorted(_MASKED)) + ") or execution='event'")
        if self.fleet.n_devices < self.clustering.n_clusters:
            raise ValueError("n_devices < n_clusters")
        return self

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FederationSpec":
        return _from_dict(cls, d, path="spec")

    def replace(self, **kw) -> "FederationSpec":
        return dataclasses.replace(self, **kw)


def _from_dict(cls, d: Dict[str, Any], path: str):
    """Recursive strict dataclass hydration: unknown keys are errors."""
    if not isinstance(d, dict):
        raise TypeError(f"{path}: expected dict, got {type(d).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = set(d) - set(fields)
    if unknown:
        raise KeyError(f"{path}: unknown keys {sorted(unknown)}; "
                       f"valid: {sorted(fields)}")
    kwargs = {}
    for name, value in d.items():
        nested = _NESTED.get((cls.__name__, name))
        kwargs[name] = (_from_dict(nested, value, f"{path}.{name}")
                        if nested else value)
    return cls(**kwargs)


_NESTED = {
    ("FederationSpec", "fleet"): FleetSpec,
    ("FederationSpec", "clustering"): ClusteringSpec,
    ("FederationSpec", "controller"): ControllerSpec,
    ("FederationSpec", "aggregator"): AggregatorSpec,
    ("FederationSpec", "task"): TaskSpec,
    ("FederationSpec", "privacy"): PrivacySpec,
    ("FederationSpec", "channel"): ChannelSpec,
    ("FederationSpec", "sharding"): ShardingSpec,
    ("FederationSpec", "faults"): FaultSpec,
}


def legacy_spec(cfg) -> FederationSpec:
    """Translate a legacy ``AsyncFLConfig`` into the equivalent spec.

    Used by the `AsyncFederation` deprecation shim; the parity test asserts
    the translation reproduces the legacy trace bit-for-bit.
    """
    if cfg.fixed_frequency is not None:
        controller = ControllerSpec("fixed", {"a": int(cfg.fixed_frequency)})
    else:
        # legacy default without an agent: constant a=5; a trained agent is
        # attached by the caller via Federation(..., controller=...)
        controller = ControllerSpec("fixed", {"a": 5})
    agg_kind = cfg.aggregator
    return FederationSpec(
        scale=DEVICE_SCALE,
        fleet=FleetSpec(n_devices=cfg.n_devices,
                        malicious_frac=cfg.malicious_frac,
                        dt_max_dev=cfg.dt_max_dev,
                        calibrate_dt=cfg.calibrate_dt),
        clustering=ClusteringSpec(n_clusters=cfg.n_clusters,
                                  alpha0=cfg.alpha0,
                                  alpha_growth=cfg.alpha_growth),
        controller=controller,
        aggregator=AggregatorSpec(kind=agg_kind),
        task=TaskSpec("mlp"),
        privacy=PrivacySpec(clip=cfg.dp_clip, noise=cfg.dp_noise),
        channel=ChannelSpec(p_good=cfg.p_good, pkt_fail=cfg.pkt_fail),
        sim_seconds=cfg.sim_seconds,
        local_batch=cfg.local_batch,
        lr=cfg.lr, iota=cfg.iota, seed=cfg.seed)
