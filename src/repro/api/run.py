"""Scenario CLI for the unified federation API.

    PYTHONPATH=src python -m repro.api.run --scenario byzantine
    PYTHONPATH=src python -m repro.api.run --scenario dp --sim-seconds 10
    PYTHONPATH=src python -m repro.api.run --scenario lm-modeA --rounds 5
    PYTHONPATH=src python -m repro.api.run --list

Each scenario is a registered preset returning a `FederationSpec`; CLI
flags override the common fields, and ``--spec-json`` dumps the resolved
spec (the config-file round-trip format) instead of running.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from .federation import Federation
from . import scenarios  # noqa: F401  (populates SCENARIOS)
from .registry import SCENARIOS
from .spec import FederationSpec, ShardingSpec


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro.api.run",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="sync-baseline",
                    help=f"one of {SCENARIOS.names()}")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and exit")
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--sim-seconds", type=float, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--clusters", type=int, default=None)
    ap.add_argument("--eval-every", type=float, default=3.0)
    ap.add_argument("--aggregator", default=None)
    ap.add_argument("--mesh", default=None,
                    help="mesh shape sharding the fleet, e.g. '8' or '4x2' "
                         "(needs that many devices; on CPU force a pool "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--impl", default=None, choices=["shard_map", "gspmd"],
                    help="sharded execution implementation for --mesh "
                         "(default: shard_map on 1-D meshes, gspmd on "
                         "multi-axis meshes)")
    ap.add_argument("--spec-json", action="store_true",
                    help="print the resolved spec as JSON and exit")
    ap.add_argument("--trace-out", default="",
                    help="write the trace records to this JSON file")
    return ap


def resolve_spec(args) -> FederationSpec:
    spec = SCENARIOS.get(args.scenario)()
    if args.seed is not None:
        spec = spec.replace(seed=args.seed)
    if args.sim_seconds is not None:
        spec = spec.replace(sim_seconds=args.sim_seconds)
    if args.rounds is not None:
        spec = spec.replace(rounds=args.rounds)
    if args.devices is not None:
        spec = spec.replace(fleet=dataclasses.replace(
            spec.fleet, n_devices=args.devices))
    if args.clusters is not None:
        spec = spec.replace(clustering=dataclasses.replace(
            spec.clustering, n_clusters=args.clusters))
    if args.aggregator is not None:
        spec = spec.replace(aggregator=dataclasses.replace(
            spec.aggregator, kind=args.aggregator))
    if args.mesh is not None:
        try:
            shape = tuple(int(d) for d in
                          args.mesh.replace("x", ",").split(","))
        except ValueError:
            raise ValueError(f"--mesh {args.mesh!r}: expected a mesh shape "
                             "like '8' or '4x2'") from None
        spec = spec.replace(sharding=ShardingSpec(mesh=shape,
                                                  impl=args.impl))
    return spec.validate()


def _config_error(e: BaseException) -> int:
    print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
    return 2


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list:
        for name in SCENARIOS.names():
            print(f"{name:16s} {SCENARIOS.get(name).__doc__.strip()}")
        return 0
    try:
        spec = resolve_spec(args)
    except (KeyError, ValueError) as e:
        return _config_error(e)
    if args.spec_json:
        print(json.dumps(spec.to_dict(), indent=2))
        return 0

    print(f"scenario={args.scenario} scale={spec.scale} "
          f"controller={spec.controller.kind} "
          f"aggregator={spec.aggregator.kind}")
    try:
        fed = Federation.from_spec(spec)
    except (KeyError, ValueError) as e:
        # component/placement resolution failures (e.g. a mesh larger than
        # the visible device pool) are config errors, not tracebacks
        return _config_error(e)
    trace = fed.run(eval_every=args.eval_every)
    print("t,round,cluster,a,loss,acc,energy,aggs")
    for r in trace.records:
        acc = f"{r.acc:.3f}" if r.acc is not None else "-"
        print(f"{r.t:7.2f},{r.round},{r.cluster},{r.a},"
              f"{r.loss:.4f},{acc},{r.energy:.1f},{r.agg_count}")
    print("summary:", json.dumps(trace.summary()))
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(trace.to_json(indent=2))
        print(f"trace written to {args.trace_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
