"""Unified federation API — the single public entry point.

    from repro.api import Federation, FederationSpec

    trace = Federation.from_spec(FederationSpec()).run()

Layers:
  spec        declarative `FederationSpec` tree (+ dict round-trip),
              including `ShardingSpec` — *where* a federation runs
  registry    named component registries with decorator registration
              (aggregators, controllers, tasks, scenarios, engines)
  components  aggregators (Eqn 6 / robust), controllers (fixed / DQN /
              Lyapunov-greedy), task adapters (mlp / lm)
  placement   `ShardingSpec` -> `jax.sharding` mesh + per-leaf-group
              NamedShardings (single-device fallback by default)
  engine      the `Engine` protocol + built-ins: device-scale
              discrete-event simulator, datacenter fl_step
  records     one `RoundRecord`/`FLTrace` schema for both scales
  run         `python -m repro.api.run --scenario ...` CLI presets

Legacy entry points (`repro.core.AsyncFederation`, `run_sync_baseline`,
`build_train_step`) keep working as thin shims; see API.md for migration.
"""
from .components import (ControllerCtx, DQNController, FixedController,
                         LMTask, LyapunovGreedyController, MLPTask,
                         RobustAggregator, WeightedAggregator)
from .engine import DatacenterEngine, DeviceScaleEngine, Engine, FleetState
from .federation import Federation
from .placement import Placement, SINGLE_DEVICE, resolve as resolve_placement
from .records import FLTrace, RoundRecord
from .registry import (AGGREGATORS, CONTROLLERS, ENGINES, SCENARIOS, TASKS,
                       register_aggregator, register_controller,
                       register_engine, register_scenario, register_task)
from .spec import (AggregatorSpec, ChannelSpec, ClusteringSpec,
                   ControllerSpec, DATACENTER_SCALE, DEVICE_SCALE,
                   FaultSpec, FederationSpec, FleetSpec, PrivacySpec,
                   ShardingSpec, TaskSpec, legacy_spec)
from . import scenarios  # noqa: F401  (populates SCENARIOS presets)

__all__ = [
    "Federation", "FederationSpec", "FleetState", "FLTrace", "RoundRecord",
    "FleetSpec", "ClusteringSpec", "ControllerSpec", "AggregatorSpec",
    "TaskSpec", "PrivacySpec", "ChannelSpec", "ShardingSpec", "FaultSpec",
    "legacy_spec",
    "DEVICE_SCALE", "DATACENTER_SCALE",
    "Engine", "DeviceScaleEngine", "DatacenterEngine",
    "Placement", "SINGLE_DEVICE", "resolve_placement",
    "AGGREGATORS", "CONTROLLERS", "ENGINES", "TASKS", "SCENARIOS",
    "register_aggregator", "register_controller", "register_engine",
    "register_task", "register_scenario",
    "WeightedAggregator", "RobustAggregator", "FixedController",
    "DQNController", "LyapunovGreedyController", "MLPTask", "LMTask",
    "ControllerCtx",
]
