"""The one trace schema both execution scales emit.

`RoundRecord` is a single evaluation point; `FLTrace` is the sequence plus
the list-style views (`times`, `accs`, ...) that the legacy
``core.async_fl.FLTrace`` exposed, so existing benchmark/plot code ports by
attribute access alone.

For runs of unbounded length (the `repro.serve` service mode) a trace can
*stream* instead of accumulate: construct it with a ``sink`` (any object
with ``append(RoundRecord)``, e.g. `JsonlSink`) and ``retain=False`` and
every record is handed to the sink without being held in memory —
``summary()`` still works off the last record and the running count.  The
batch default (``retain=True``, no sink) is unchanged.  `read_jsonl_trace`
loads a streamed file back into an in-memory trace; `tail_jsonl` reads the
last records of an arbitrarily long file without loading it (the service
``status`` command's live-metrics path).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, List, Optional


@dataclasses.dataclass
class RoundRecord:
    t: float                    # simulated seconds (device) / round (lm)
    round: int                  # global round counter
    cluster: int                # cluster that triggered this record
    a: int                      # local-update count a_i chosen that round
    loss: float
    acc: Optional[float]        # None for tasks without a notion of accuracy
    energy: float               # cumulative simulated energy [J]
    agg_count: int              # global aggregations so far

    @classmethod
    def from_dict(cls, d: dict) -> "RoundRecord":
        return cls(**{f.name: d.get(f.name)
                      for f in dataclasses.fields(cls)})


class JsonlSink:
    """Append-only JSONL writer: one record dict per line.

    Accepts dataclass records (`RoundRecord`) or plain dicts (the
    ``metrics.jsonl`` span/snapshot/event records).  The file handle
    stays open across appends (a segment flushes K records in a burst)
    and every line is flushed immediately, so an external ``tail -f`` —
    or the service ``status`` command — sees records as they land.
    Appending to an existing file continues it, which is exactly what a
    resumed run wants.  Every append stat-checks the path against the
    open handle's inode and re-opens if the file was rotated or unlinked
    underneath it, so log rotation of a long-serving run can't silently
    drop records into an orphaned handle.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._f = None

    def _ensure_open(self) -> None:
        if self._f is not None:
            # rotation check: same inode+device still at our path?
            try:
                st = os.stat(self.path)
                fst = os.fstat(self._f.fileno())
                if (st.st_ino, st.st_dev) == (fst.st_ino, fst.st_dev):
                    return
            except OSError:
                pass                    # unlinked / renamed away
            self._f.close()
            self._f = None
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a")

    def append(self, rec) -> None:
        if dataclasses.is_dataclass(rec):
            rec = dataclasses.asdict(rec)
        self._ensure_open()
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


@dataclasses.dataclass
class FLTrace:
    records: List[RoundRecord] = dataclasses.field(default_factory=list)
    sink: Optional[Any] = None       # .append(RoundRecord) tap, e.g. JsonlSink
    retain: bool = True              # False: stream-only (records stays empty)
    n_records: int = dataclasses.field(default=0, init=False)
    last: Optional[RoundRecord] = dataclasses.field(default=None, init=False)

    def __post_init__(self):
        self.n_records = len(self.records)
        self.last = self.records[-1] if self.records else None

    def append(self, rec: RoundRecord):
        self.n_records += 1
        self.last = rec
        if self.sink is not None:
            self.sink.append(rec)
        if self.retain:
            self.records.append(rec)

    # legacy list views ------------------------------------------------ #
    @property
    def times(self):
        return [r.t for r in self.records]

    @property
    def accs(self):
        return [r.acc for r in self.records]

    @property
    def losses(self):
        return [r.loss for r in self.records]

    @property
    def energies(self):
        return [r.energy for r in self.records]

    @property
    def agg_counts(self):
        return [r.agg_count for r in self.records]

    # ------------------------------------------------------------------ #
    def to_dicts(self):
        return [dataclasses.asdict(r) for r in self.records]

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dicts(), **kw)

    def summary(self) -> dict:
        if self.last is None:
            return {}
        last = self.last
        return {"final_loss": last.loss, "final_acc": last.acc,
                "energy": last.energy, "aggregations": last.agg_count,
                "rounds": last.round, "evals": self.n_records}


# --------------------------------------------------------------------- #
# JSONL trace files (the streamed form)
# --------------------------------------------------------------------- #
def read_jsonl_trace(path: str) -> FLTrace:
    """Load a streamed trace file back into an in-memory `FLTrace`.

    A torn **final** line — the signature of a writer killed
    mid-`JsonlSink.append` (the chaos harness produces these on every
    SIGKILL) — is skipped, so status/resume on a crashed run dir works.
    An unparseable line *followed by* further records is real corruption
    and still raises.
    """
    trace = FLTrace()
    torn: Optional[json.JSONDecodeError] = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if torn is not None:        # bad line was not the last one
                raise torn
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                torn = e
                continue
            trace.append(RoundRecord.from_dict(rec))
    return trace


def tail_jsonl(path: str, n: int = 10, block: int = 8192) -> List[dict]:
    """Last ``n`` records of a JSONL file, reading only its tail.

    Seeks backward in ``block``-byte chunks until enough newlines are in
    hand, so ``status`` on a multi-gigabyte trace stays O(n) — the whole
    point of streaming the trace in the first place.  Returns parsed dicts
    oldest-first; a torn final line (a writer mid-append) is skipped.
    """
    try:
        size = os.path.getsize(path)
    except OSError:
        return []
    if size == 0:
        return []
    chunks = []
    newlines = 0
    with open(path, "rb") as f:
        pos = size
        while pos > 0 and newlines <= n:
            step = min(block, pos)
            pos -= step
            f.seek(pos)
            chunk = f.read(step)
            chunks.append(chunk)
            newlines += chunk.count(b"\n")
    data = b"".join(reversed(chunks))
    out = []
    for line in data.splitlines()[-(n + 1):]:
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            continue                  # torn head (partial first line) / tail
    return out[-n:]
