"""The one trace schema both execution scales emit.

`RoundRecord` is a single evaluation point; `FLTrace` is the sequence plus
the list-style views (`times`, `accs`, ...) that the legacy
``core.async_fl.FLTrace`` exposed, so existing benchmark/plot code ports by
attribute access alone.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional


@dataclasses.dataclass
class RoundRecord:
    t: float                    # simulated seconds (device) / round (lm)
    round: int                  # global round counter
    cluster: int                # cluster that triggered this record
    a: int                      # local-update count a_i chosen that round
    loss: float
    acc: Optional[float]        # None for tasks without a notion of accuracy
    energy: float               # cumulative simulated energy [J]
    agg_count: int              # global aggregations so far


@dataclasses.dataclass
class FLTrace:
    records: List[RoundRecord] = dataclasses.field(default_factory=list)

    def append(self, rec: RoundRecord):
        self.records.append(rec)

    # legacy list views ------------------------------------------------ #
    @property
    def times(self):
        return [r.t for r in self.records]

    @property
    def accs(self):
        return [r.acc for r in self.records]

    @property
    def losses(self):
        return [r.loss for r in self.records]

    @property
    def energies(self):
        return [r.energy for r in self.records]

    @property
    def agg_counts(self):
        return [r.agg_count for r in self.records]

    # ------------------------------------------------------------------ #
    def to_dicts(self):
        return [dataclasses.asdict(r) for r in self.records]

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dicts(), **kw)

    def summary(self) -> dict:
        if not self.records:
            return {}
        last = self.records[-1]
        return {"final_loss": last.loss, "final_acc": last.acc,
                "energy": last.energy, "aggregations": last.agg_count,
                "rounds": last.round, "evals": len(self.records)}
