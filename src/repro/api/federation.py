"""`Federation`: the single public entry point of the repro.

    from repro.api import Federation, FederationSpec

    spec = FederationSpec(...)               # or FederationSpec.from_dict(...)
    trace = Federation.from_spec(spec).run()

Component instances built from the registries can be overridden with live
objects (e.g. a DQN agent you trained yourself) via keyword arguments.

The facade holds no per-scale code: ``spec.scale`` is a key into the
`ENGINES` registry and every engine is constructed through the uniform
`Engine.from_spec` protocol (see `repro.api.engine`), so registering a new
engine class makes it reachable from specs, config files, and the CLI
without touching this module.
"""
from __future__ import annotations

from . import registry
from .engine import Engine  # noqa: F401  (re-export; also populates ENGINES)
from .records import FLTrace
from .spec import DEVICE_SCALE, FederationSpec


class Federation:
    """Facade tying spec -> components -> engine -> trace."""

    def __init__(self, spec: FederationSpec, *, data=None, parts=None,
                 controller=None, aggregator=None, task=None, fused=None):
        spec.validate()
        self.spec = spec
        self.controller = controller or registry.CONTROLLERS.get(
            spec.controller.kind)(spec.controller.params)
        params = dict(spec.aggregator.params)
        if spec.scale == DEVICE_SCALE:
            params.setdefault("use_kernel", spec.aggregator.use_kernel)
        self.aggregator = aggregator or registry.AGGREGATORS.get(
            spec.aggregator.kind)(params)
        self.task = task or registry.TASKS.get(spec.task.kind)(
            spec.task.params)
        self.engine: Engine = registry.ENGINES.get(spec.scale).from_spec(
            spec, controller=self.controller, aggregator=self.aggregator,
            task=self.task, data=data, parts=parts, fused=fused)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_spec(cls, spec: FederationSpec, **kw) -> "Federation":
        return cls(spec, **kw)

    @classmethod
    def from_dict(cls, d: dict, **kw) -> "Federation":
        return cls(FederationSpec.from_dict(d), **kw)

    def run(self, eval_every: float = 1.0, **kw) -> FLTrace:
        """Extra keywords (e.g. the device engine's ``max_rounds``) pass
        through to the engine's run."""
        return self.engine.run(eval_every=eval_every, **kw)

    # convenience passthroughs (device scale) -------------------------- #
    def __getattr__(self, name):
        if name == "engine":                 # not yet set: avoid recursion
            raise AttributeError(name)
        return getattr(self.engine, name)
