"""`Federation`: the single public entry point of the repro.

    from repro.api import Federation, FederationSpec

    spec = FederationSpec(...)               # or FederationSpec.from_dict(...)
    trace = Federation.from_spec(spec).run()

Component instances built from the registries can be overridden with live
objects (e.g. a DQN agent you trained yourself) via keyword arguments.
"""
from __future__ import annotations

from typing import Optional

import jax

from . import registry
from .records import FLTrace
from .spec import DATACENTER_SCALE, DEVICE_SCALE, FederationSpec


class Federation:
    """Facade tying spec -> components -> engine -> trace."""

    def __init__(self, spec: FederationSpec, *, data=None, parts=None,
                 controller=None, aggregator=None, task=None, fused=None):
        spec.validate()
        self.spec = spec
        self.controller = controller or registry.CONTROLLERS.get(
            spec.controller.kind)(spec.controller.params)
        params = dict(spec.aggregator.params)
        if spec.scale == DEVICE_SCALE:
            params.setdefault("use_kernel", spec.aggregator.use_kernel)
        self.aggregator = aggregator or registry.AGGREGATORS.get(
            spec.aggregator.kind)(params)
        self.task = task or registry.TASKS.get(spec.task.kind)(
            spec.task.params)

        if spec.scale == DEVICE_SCALE:
            from .engine import DeviceScaleEngine
            if data is None or parts is None:
                data, parts = _default_device_data(spec)
            self.engine = DeviceScaleEngine(
                spec, data, parts, controller=self.controller,
                aggregator=self.aggregator, task=self.task, fused=fused)
        elif spec.scale == DATACENTER_SCALE:
            from .engine import DatacenterEngine
            self.engine = DatacenterEngine(
                spec, controller=self.controller, task=self.task)
        else:
            raise ValueError(spec.scale)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_spec(cls, spec: FederationSpec, **kw) -> "Federation":
        return cls(spec, **kw)

    @classmethod
    def from_dict(cls, d: dict, **kw) -> "Federation":
        return cls(FederationSpec.from_dict(d), **kw)

    def run(self, eval_every: float = 1.0, **kw) -> FLTrace:
        """Extra keywords (e.g. the device engine's ``max_rounds``) pass
        through to the engine's run."""
        return self.engine.run(eval_every=eval_every, **kw)

    # convenience passthroughs (device scale) -------------------------- #
    def __getattr__(self, name):
        if name == "engine":                 # not yet set: avoid recursion
            raise AttributeError(name)
        return getattr(self.engine, name)


def _default_device_data(spec: FederationSpec):
    """Synthetic non-IID federated data from the task params."""
    from repro.data import dirichlet_partition, make_classification
    p = spec.task.params
    key = jax.random.PRNGKey(spec.seed)
    data = make_classification(key, n=p.get("n_samples", 4096),
                               dim=p.get("dim", 784))
    parts = dirichlet_partition(key, data.y, spec.fleet.n_devices,
                                alpha=p.get("dirichlet_alpha", 0.5))
    return data, parts
