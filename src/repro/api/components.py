"""Pluggable federation components and their registry entries.

Three component protocols, all duck-typed — and all **jit-safe**: the fused
`FleetState` round traces aggregator and task calls into one compiled
program, so their bodies must be pure jnp (no host syncs, no Python control
flow on traced values).

Aggregator        ``__call__(client_params, weights, mask=None) -> pytree``
                  (client_params leaves carry a leading client dim).  Class
                  attr ``supports_mask``: True means the rule understands a
                  (C,) validity mask and the engine may run it on *padded*
                  fixed-shape clusters sharing one compiled round; False
                  (the default for third-party callables) makes the engine
                  compile one exact-shape round per cluster size instead.
FrequencyController
                  ``select(ctx) -> int`` raw a_i before the Alg.-2 tolerance
                  bound (applied *inside* the jitted round); optional
                  ``observe(ctx, consumed, loss)`` feedback hook after the
                  round; ``n_actions`` caps a_i.  Class attr ``needs_ctx``:
                  False lets the engine skip materializing the host-side
                  `ControllerCtx` (device->host syncs) each round.  An
                  optional ``scan_policy() -> repro.control.ScanPolicy``
                  provides the in-jit twin of `select` that
                  `DeviceScaleEngine.run_scanned` traces into its
                  lax.scan-over-rounds (all built-ins implement it).
TaskAdapter       model/task plug: init / loss / local training / metrics.
                  ``local_train`` must accept a *traced* step count (the
                  tolerance bound is computed inside jit).

Registration makes every paper mechanism (trust Eqn 6, robust baselines,
DQN Alg. 1, Lyapunov Eqn 12-15) a named choice in `FederationSpec`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import policy as ctl_policy
from repro.control.scanned_dqn import train_on_env
from repro.core import dqn as dqn_lib
from repro.core import envs
from repro.core.autoencoder import (anomaly_auc, code_mean,
                                    init_mlp_autoencoder,
                                    reconstruction_errors,
                                    reconstruction_loss)
from repro.core.lyapunov import init_queue, step_queue
from repro.core.mlp import (accuracy, classifier_loss, init_mlp_classifier,
                            mlp_hidden_mean)
from repro.core.robust import AGGREGATORS as ROBUST_RULES
from repro.core.robust import MASKED_AGGREGATORS as MASKED_RULES
from repro.core.trust import trust_weighted_average
from repro.core.twin import calibrated_freq
from repro.kernels.ops import (INTERPRET, trust_aggregate_global_tree,
                               trust_aggregate_tree)

from .registry import (register_aggregator, register_controller,
                       register_task)


# --------------------------------------------------------------------- #
# controller context
# --------------------------------------------------------------------- #
class ControllerCtx(NamedTuple):
    """What a frequency controller may look at when choosing a_i."""
    round: int                       # global round counter
    cluster: int                     # cluster index being scheduled
    obs: Callable[[], jnp.ndarray]   # lazy DQN observation (OBS_DIM,)
    cluster_loss: float              # mean twin loss over the cluster
    cluster_freq: float              # straggler (min) calibrated frequency
    mean_freq: float                 # mean calibrated frequency in cluster
    channel_good_frac: float         # fraction of members in the good state
    energy_used: float               # cumulative energy so far


# --------------------------------------------------------------------- #
# aggregators (Eqn 6 + robust baselines)
# --------------------------------------------------------------------- #
class WeightedAggregator:
    """Trust/uniform weighted average; hot path through the Pallas
    ``trust_aggregate`` kernel (interpret=True on CPU), jnp fallback.
    Mask-aware: padded client rows carry zero weight, so ragged cluster
    memberships run as one fixed-shape compiled round."""

    supports_mask = True

    def __init__(self, uniform: bool = False, use_kernel: bool = True):
        self.uniform = uniform
        self.use_kernel = use_kernel
        # the kernel path can fold the Eqn-19 global average into the same
        # grid pass (`aggregate_with_global`); the engine consults this
        self.supports_fused_global = use_kernel

    def _effective_weights(self, weights, mask):
        if not self.uniform:
            return weights
        if mask is None:
            return jnp.full_like(weights, 1.0 / weights.shape[0])
        m = mask.astype(weights.dtype)
        return m / jnp.maximum(jnp.sum(m), 1.0)

    def __call__(self, client_params, weights, mask=None):
        weights = self._effective_weights(weights, mask)
        if self.use_kernel:
            return trust_aggregate_tree(client_params, weights, mask,
                                        interpret=INTERPRET)
        if mask is not None:
            weights = weights * mask.astype(weights.dtype)
        return trust_weighted_average(client_params, weights)

    def aggregate_with_global(self, client_params, weights, mask,
                              cluster_stack, staleness_w, c):
        """Fused Eqn 6 + Eqn 19: member updates -> the post-round global
        model in one `trust_aggregate_global` kernel pass (the Eqn-6
        aggregate replaces row ``c`` of the stacked cluster parameters
        in-VMEM before the staleness-weighted average)."""
        weights = self._effective_weights(weights, mask)
        return trust_aggregate_global_tree(
            client_params, weights, mask, cluster_stack, staleness_w, c,
            interpret=INTERPRET)


class RobustAggregator:
    """Byzantine-robust rules from repro.core.robust; ignores trust weights
    (that is their point: no reputation signal needed).  Rules with a
    fixed-capacity masked variant (`median` / `trimmed_mean`, via the
    ±inf-padded sorts in `robust`) advertise ``supports_mask=True`` and
    join the engine's padded fused round; the remaining rank statistics
    (krum, multi-krum) run on exact-shape clusters — one compile per
    distinct cluster size."""

    def __init__(self, rule: str, **kw):
        self.rule_name = rule
        self._rule = ROBUST_RULES[rule]
        self._masked_rule = MASKED_RULES.get(rule)
        self.supports_mask = self._masked_rule is not None
        self._kw = kw

    def __call__(self, client_params, weights, mask=None):
        del weights
        if mask is not None:
            if self._masked_rule is None:
                raise ValueError(f"{self.rule_name} cannot run on padded "
                                 "clusters (supports_mask=False)")
            return self._masked_rule(client_params, mask, **self._kw)
        return self._rule(client_params, **self._kw)


@register_aggregator("trust")
def _trust(params: Dict[str, Any]):
    return WeightedAggregator(uniform=False,
                              use_kernel=params.get("use_kernel", True))


@register_aggregator("fedavg")
def _fedavg(params: Dict[str, Any]):
    return WeightedAggregator(uniform=True,
                              use_kernel=params.get("use_kernel", True))


def _register_robust(name):
    @register_aggregator(name)
    def _build(params: Dict[str, Any], _name=name):
        return RobustAggregator(_name, **{k: v for k, v in params.items()
                                          if k != "use_kernel"})


for _name in ROBUST_RULES:
    _register_robust(_name)


# --------------------------------------------------------------------- #
# frequency controllers
# --------------------------------------------------------------------- #
class FixedController:
    """Benchmark scheme: constant a_i (still tolerance-bounded by Alg. 2).
    ``needs_ctx=False``: the engine skips the per-round host-side ctx
    (device syncs) entirely — the fused-round fast path."""

    needs_ctx = False

    def __init__(self, a: int = 5, n_actions: int = 10):
        self.a = int(a)
        self.n_actions = int(n_actions)

    def select(self, ctx: ControllerCtx) -> int:
        return self.a

    def observe(self, ctx, consumed, loss):
        pass

    def scan_policy(self) -> ctl_policy.ScanPolicy:
        return ctl_policy.fixed_policy(self.a)


class DQNController:
    """Greedy policy of a trained Alg.-1 DQN agent.

    Build from a live agent (``DQNController(agent, cfg)``) or let the
    registry factory train one on the DT-simulated environment — the paper's
    headline mechanism: the agent interacts with the twins, not the devices.
    """

    needs_ctx = True                    # select() reads the DQN observation

    def __init__(self, agent: dqn_lib.DQNState, cfg: dqn_lib.DQNConfig):
        self.agent = agent
        self.cfg = cfg
        self.n_actions = cfg.n_actions

    def select(self, ctx: ControllerCtx) -> int:
        q = dqn_lib.q_values(self.agent.eval_params, ctx.obs())
        return int(jnp.argmax(q)) + 1

    def observe(self, ctx, consumed, loss):
        pass

    def scan_policy(self) -> ctl_policy.ScanPolicy:
        return ctl_policy.dqn_policy(self.agent.eval_params)

    def distill(self, **kw) -> ctl_policy.PolicyTable:
        """Freeze the greedy head into a lookup table
        (`repro.control.distill_table`) for microsecond selects."""
        return ctl_policy.distill_table(self.agent.eval_params, **kw)

    def restore_policy_state(self, eval_params) -> None:
        """Adopt a checkpointed scan-policy carry (`repro.serve` restores
        the exact deployed net rather than relying on the registry's
        deterministic re-pretrain)."""
        self.agent = self.agent._replace(eval_params=eval_params)

    @classmethod
    def pretrain(cls, seed: int = 0, episodes: int = 4, horizon: int = 25,
                 p_good: float = 0.5, calibrate_dt: bool = True,
                 buffer_size: int = 512, batch_size: int = 32,
                 lr: float = 2e-3) -> "DQNController":
        """Train a fresh agent on the DT environment (§IV-C, Alg. 1).

        The whole run — episodes of epsilon-greedy interaction, replay
        writes, TD steps, target syncs — lowers into one nested `lax.scan`
        (`repro.control.scanned_dqn.train_on_env`); no host episode loop.
        """
        p = envs.EnvParams(horizon=horizon, p_good=p_good,
                           calibrate_dt=calibrate_dt)
        cfg = dqn_lib.DQNConfig(buffer_size=buffer_size,
                                batch_size=batch_size, lr=lr)
        agent = dqn_lib.init_dqn(jax.random.PRNGKey(seed), cfg)
        agent, _ = train_on_env(jax.random.PRNGKey(seed + 1), agent, cfg, p,
                                episodes=episodes)
        return cls(agent, cfg)


class LyapunovGreedyController:
    """One-step drift-plus-penalty greedy controller (Eqns 12-15).

    No learned policy: each slot it scores every a in {1..n_actions} with
    the paper's P2 objective  v·ΔF̂(a) − Q(i)·(a·Ê_cmp + Ê_com)  using the
    twin-estimated energy and an exponential loss-decay model, picks the
    argmax, and advances the deficit queue with the realized consumption.
    A model-free baseline between `fixed` and the trained DQN.

    Scoring goes through `repro.control.policy.lyapunov_scores` — the same
    f32 device math the in-jit `scan_policy` traces into the fused round —
    so the event-heap and scanned execution paths pick identical actions
    (jnp.argmax and the old strict-greater host loop both keep the earliest
    maximum on ties).
    """

    needs_ctx = True          # select() scores the P2 objective from ctx

    def __init__(self, budget: float = 250.0, horizon: int = 100,
                 kappa: float = 0.08, f_star: float = 0.1,
                 v0: float = 1.0, v_growth: float = 0.02,
                 n_actions: int = 10):
        self.queue = init_queue(budget, horizon)
        self.kappa = kappa
        self.f_star = f_star
        self.v0 = v0
        self.v_growth = v_growth
        self.n_actions = int(n_actions)

    def select(self, ctx: ControllerCtx) -> int:
        scores = ctl_policy.lyapunov_scores(
            self.queue.q, jnp.float32(ctx.round),
            jnp.float32(ctx.cluster_loss), jnp.float32(ctx.mean_freq),
            jnp.float32(ctx.channel_good_frac), n_actions=self.n_actions,
            kappa=self.kappa, f_star=self.f_star, v0=self.v0,
            v_growth=self.v_growth)
        return int(jnp.argmax(scores)) + 1

    def observe(self, ctx, consumed, loss):
        self.queue = step_queue(self.queue, consumed)

    def scan_policy(self) -> ctl_policy.ScanPolicy:
        """In-jit twin reading the Eqn-12 backlog off `FleetState.queue`
        (the engine advances that leaf with the same realized consumption
        `observe` sees on the host path)."""
        return ctl_policy.lyapunov_policy(
            n_actions=self.n_actions, kappa=self.kappa, f_star=self.f_star,
            v0=self.v0, v_growth=self.v_growth)

    def sync_queue(self, q) -> None:
        """Adopt the device-resident backlog after a scanned run so later
        host-side selects continue from the same deficit."""
        self.queue = self.queue._replace(q=jnp.asarray(q, jnp.float32))


@register_controller("fixed")
def _fixed(params: Dict[str, Any]):
    return FixedController(a=params.get("a", 5),
                           n_actions=params.get("n_actions", 10))


@register_controller("dqn")
def _dqn(params: Dict[str, Any]):
    agent = params.get("agent")
    if agent is not None:
        return DQNController(agent, params.get(
            "dqn_cfg", dqn_lib.DQNConfig()))
    kw = {k: v for k, v in params.items() if k not in ("agent", "dqn_cfg")}
    return DQNController.pretrain(**kw)


@register_controller("lyapunov")
def _lyapunov(params: Dict[str, Any]):
    return LyapunovGreedyController(**params)


# --------------------------------------------------------------------- #
# task adapters
# --------------------------------------------------------------------- #
class MLPTask:
    """The paper's device-scale MNIST-shaped classifier.

    jit-safe: ``local_train`` takes the step count as a *traced* scalar
    (fori_loop with a dynamic trip count), so the fused round can apply the
    Alg.-2 tolerance bound inside the compiled program without a per-value
    recompile."""

    def __init__(self, hidden: int = 200, n_classes: int = 10):
        self.hidden = hidden
        self.n_classes = n_classes
        self._client_sgd_v = jax.jit(
            jax.vmap(self._client_sgd, in_axes=(0, 0, None, None)))
        self._losses_v = jax.vmap(classifier_loss, in_axes=(0, 0))

    @staticmethod
    def _client_sgd(params, batch, lr, steps):
        def one(_, p):
            g = jax.grad(classifier_loss)(p, batch)
            return jax.tree.map(lambda a, b: a - lr * b, p, g)
        return jax.lax.fori_loop(0, steps, one, params)

    def init(self, key, dim: int):
        return init_mlp_classifier(key, dim=dim, hidden=self.hidden,
                                   n_classes=self.n_classes)

    def local_train(self, stacked_params, batch, lr: float, steps: int):
        """vmap-ed a_i SGD steps over the member dim."""
        return self._client_sgd_v(stacked_params, batch, lr, steps)

    def losses(self, stacked_params, batch):
        return self._losses_v(stacked_params, batch)

    def loss(self, params, batch):
        return classifier_loss(params, batch)

    def evaluate(self, params, data) -> Dict[str, float]:
        return {
            "acc": float(accuracy(params, data.x, data.y)),
            "loss": float(classifier_loss(
                params, {"x": data.x[:1024], "y": data.y[:1024]})),
        }

    def hidden_mean(self, params, x):
        return mlp_hidden_mean(params, x)

    def corrupt_labels(self, y):
        """Byzantine label flip used by malicious members."""
        return (y + 1) % self.n_classes


class AutoencoderAnomalyTask:
    """Federated autoencoder anomaly detection over IoT telemetry — the
    first non-classification workload (FedIoT-style, SNIPPETS.md §3).

    Same engine contract as `MLPTask` (jit-safe ``local_train`` with a
    traced step count, vmapped per-member losses), but the loss is the mean
    squared *reconstruction* error and training is unsupervised — batch
    labels carry the anomaly ground truth for evaluation only, so the
    Eqn-4/5 trust pipeline (learning quality, gradient diversity, belief)
    runs on reconstruction gradients exactly as it does on classification
    gradients.  ``evaluate`` reports the reconstruction loss plus the
    threshold-free detection AUC of per-sample errors against the labels
    (surfacing in the trace's ``acc`` field).

    Byzantine label-flipping has no lever here (the training loss never
    reads labels), so ``corrupt_labels`` is the identity — model input
    poisoning instead via a custom task if needed.
    """

    def __init__(self, hidden: int = 64, code: int = 8):
        self.hidden = hidden
        self.code = code
        self._client_sgd_v = jax.jit(
            jax.vmap(self._client_sgd, in_axes=(0, 0, None, None)))
        self._losses_v = jax.vmap(reconstruction_loss, in_axes=(0, 0))

    @staticmethod
    def _client_sgd(params, batch, lr, steps):
        def one(_, p):
            g = jax.grad(reconstruction_loss)(p, batch)
            return jax.tree.map(lambda a, b: a - lr * b, p, g)
        return jax.lax.fori_loop(0, steps, one, params)

    def init(self, key, dim: int):
        return init_mlp_autoencoder(key, dim=dim, hidden=self.hidden,
                                    code=self.code)

    def local_train(self, stacked_params, batch, lr: float, steps: int):
        """vmap-ed a_i SGD steps on the reconstruction loss."""
        return self._client_sgd_v(stacked_params, batch, lr, steps)

    def losses(self, stacked_params, batch):
        return self._losses_v(stacked_params, batch)

    def loss(self, params, batch):
        return reconstruction_loss(params, batch)

    def evaluate(self, params, data) -> Dict[str, float]:
        scores = reconstruction_errors(params, data.x)
        auc = float(anomaly_auc(scores, data.y))
        return {
            "acc": None if np.isnan(auc) else auc,   # detection AUC
            "loss": float(jnp.mean(scores[:1024])),
        }

    def hidden_mean(self, params, x):
        return code_mean(params, x)

    def corrupt_labels(self, y):
        return y          # unsupervised: labels never enter the loss


class LMTask:
    """Datacenter-scale LM task over the sharded fl_step modes.

    ``arch`` names a smoke config from repro.configs, or pass explicit tiny
    dims (d_model/num_layers/...) for a self-contained config.
    """

    def __init__(self, arch: Optional[str] = None, mode: str = "fedavg_replica",
                 seq: int = 16, micro_batch: int = 2, n_micro: int = 1,
                 local_steps: int = 1, lr: float = 3e-4, **dims):
        from repro.models import ArchConfig
        if arch:
            from repro.configs import get_smoke_config
            self.cfg = get_smoke_config(arch)
        else:
            base = dict(name="api-tiny", arch_type="dense", num_layers=2,
                        d_model=32, vocab_size=64, num_heads=2,
                        num_kv_heads=1, d_ff=64)
            base.update(dims)
            self.cfg = ArchConfig(**base)
        self.mode = mode
        self.seq = seq
        self.micro_batch = micro_batch
        self.n_micro = n_micro
        self.local_steps = local_steps
        self.lr = lr

    def make_batch(self, key, n_clusters: int, clients: int):
        from repro.core.fl_step import MODE_B
        from repro.data import token_stream
        if self.mode == MODE_B:
            shape = (n_clusters, self.n_micro, self.micro_batch, self.seq + 1)
        else:
            shape = (n_clusters, clients, self.n_micro, self.micro_batch,
                     self.seq + 1)
        if self.cfg.num_codebooks > 1:
            shape = shape[:-1] + (self.cfg.num_codebooks, self.seq + 1)
        toks = token_stream(key, int(np.prod(shape)),
                            self.cfg.vocab_size).reshape(shape)
        batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if self.mode == MODE_B:
            # trust enters as per-example loss weights in mode B
            batch["weights"] = jnp.ones(
                (n_clusters, self.n_micro, self.micro_batch))
        return batch


@register_task("mlp")
def _mlp(params: Dict[str, Any]):
    return MLPTask(**{k: v for k, v in params.items()
                      if k in ("hidden", "n_classes")})


@register_task("autoencoder-anomaly")
def _autoencoder(params: Dict[str, Any]):
    # data-generation params (n_samples/dim/n_types/...) are consumed by
    # `engine.default_device_data`; only the model dims reach the task
    return AutoencoderAnomalyTask(**{k: v for k, v in params.items()
                                     if k in ("hidden", "code")})


@register_task("lm")
def _lm(params: Dict[str, Any]):
    return LMTask(**params)
