"""Placement: resolve a `ShardingSpec` into concrete device placement.

*Where* a federation runs is spec data like everything else
(`FederationSpec.sharding`); this module turns that data into a
`Placement` — a `jax.sharding.Mesh` plus one `NamedSharding` per
`FleetState` leaf *group*:

  device group      leaves with leading dim n_devices (twins, rep,
                    channel), partitioned over ``device_axis``
  cluster group     leaves with leading dim n_clusters (the stacked
                    per-cluster parameters, cluster timestamps, and the
                    scan's per-cluster event-time vector), partitioned
                    over ``cluster_axis``
  replicated        everything else — the global model, the Eqn-12 queue
                    scalar, the round counter, the RNG key

The single-device fallback (``mesh=()``) resolves to ``SINGLE_DEVICE``,
whose shardings are all None: the engine then builds exactly the
pre-placement jits, so the default spec is bit-identical to the old
behavior.  A 1-device mesh (``mesh=(1,)``) builds a real `Mesh` and goes
through the sharded jit path — the placement-parity test pins that this
too reproduces the unsharded trace bit for bit.

The engine consumes a `Placement` through jit ``in_shardings`` /
``out_shardings`` on the fused round and the lax.scan-over-rounds: XLA's
SPMD partitioner then keeps per-shard work local and inserts the
all-reduces the Eqn-19 global average needs.  (A ``shard_map`` around the
padded membership gathers would make locality explicit instead of
inferred; that needs shard-aligned cluster memberships, which k-means
does not give — see API.md "Placement".)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .spec import ShardingSpec

# FleetState field -> leaf-group membership (leading-dim semantics)
DEVICE_GROUP = ("twins", "rep", "channel")
CLUSTER_GROUP = ("cluster_params", "cluster_ts")


@dataclasses.dataclass(frozen=True)
class Placement:
    """A resolved mesh + the axis each FleetState leaf group shards on."""
    mesh: Optional[Mesh] = None
    device_axis: Optional[str] = None
    cluster_axis: Optional[str] = None

    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    # ------------------------------------------------------------------ #
    def sharding(self, axis: Optional[str] = None) -> Optional[NamedSharding]:
        """NamedSharding partitioning the leading dim over ``axis``
        (None = replicated).  Returns None on the single-device fallback."""
        if self.mesh is None:
            return None
        spec = PartitionSpec() if axis is None else PartitionSpec(axis)
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> Optional[NamedSharding]:
        return self.sharding(None)

    def group_axis(self, field: str) -> Optional[str]:
        if field in DEVICE_GROUP:
            return self.device_axis
        if field in CLUSTER_GROUP:
            return self.cluster_axis
        return None

    def state_shardings(self, state) -> Any:
        """A pytree of NamedShardings matching a `FleetState` (any NamedTuple
        whose field names follow the leaf-group convention)."""
        out = {}
        for field in state._fields:
            sh = self.sharding(self.group_axis(field))
            out[field] = jax.tree.map(lambda _: sh, getattr(state, field))
        return type(state)(**out)

    def tree_replicated(self, tree) -> Any:
        repl = self.replicated()
        return jax.tree.map(lambda _: repl, tree)

    def shard_state(self, state) -> Any:
        """Commit a FleetState's leaves to their group shardings."""
        if not self.is_sharded:
            return state
        return jax.device_put(state, self.state_shardings(state))


SINGLE_DEVICE = Placement()


def resolve(sharding: ShardingSpec, *, n_devices: int,
            n_clusters: int) -> Placement:
    """`ShardingSpec` -> `Placement` over this process's visible devices.

    Raises with a readable error when the mesh does not divide the fleet
    (delegated to ``ShardingSpec.validate``) or needs more devices than
    the backend exposes.
    """
    if not sharding.is_sharded:
        return SINGLE_DEVICE
    sharding.validate(n_devices, n_clusters)
    need = math.prod(sharding.mesh)
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"sharding: mesh {sharding.mesh} needs {need} devices but the "
            f"{devices[0].platform} backend exposes {len(devices)}; on a "
            "CPU host, force a device pool with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    axes = sharding.resolved_axes()
    mesh = Mesh(np.asarray(devices[:need]).reshape(sharding.mesh), axes)
    return Placement(mesh=mesh, device_axis=sharding.device_axis,
                     cluster_axis=sharding.resolved_cluster_axis(axes))
