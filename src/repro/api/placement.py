"""Placement: resolve a `ShardingSpec` into concrete device placement.

*Where* a federation runs is spec data like everything else
(`FederationSpec.sharding`); this module turns that data into a
`Placement` — a `jax.sharding.Mesh` plus one `NamedSharding` per
`FleetState` leaf *group*:

  device group      leaves with leading dim n_devices (twins, rep,
                    channel), partitioned over ``device_axis``
  cluster group     leaves with leading dim n_clusters (the stacked
                    per-cluster parameters, cluster timestamps, and the
                    scan's per-cluster event-time vector), partitioned
                    over ``cluster_axis``
  replicated        everything else — the global model, the Eqn-12 queue
                    scalar, the round counter, the RNG key

The single-device fallback (``mesh=()``) resolves to ``SINGLE_DEVICE``,
whose shardings are all None: the engine then builds exactly the
pre-placement jits, so the default spec is bit-identical to the old
behavior.  A 1-device mesh (``mesh=(1,)``) builds a real `Mesh` and goes
through the sharded jit path — the placement-parity test pins that this
too reproduces the unsharded trace bit for bit.

Two sharded implementations consume a `Placement`:

* ``impl='gspmd'`` (the PR-5 path): jit ``in_shardings`` /
  ``out_shardings`` on the fused round and the lax.scan-over-rounds;
  XLA's SPMD partitioner infers the collectives.  Membership gathers are
  not shard-aligned under k-means, so the partitioner inserts cross-shard
  all-gathers — this path measures partitioning overhead, not capacity.
* ``impl='shard_map'`` (the cluster-major engine,
  `repro.api.cluster_engine`): the fleet is statically re-indexed so each
  cluster's member slots are contiguous, every leaf co-shards over one
  mesh axis (``shard_map_placement`` below), and the round is an explicit
  `jax.shard_map` whose only collectives are two psums.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .spec import GSPMD_IMPL, ShardingSpec

# FleetState field -> leaf-group membership (leading-dim semantics)
DEVICE_GROUP = ("twins", "rep", "channel")
CLUSTER_GROUP = ("cluster_params", "cluster_ts")


@dataclasses.dataclass(frozen=True)
class Placement:
    """A resolved mesh + the axis each FleetState leaf group shards on."""
    mesh: Optional[Mesh] = None
    device_axis: Optional[str] = None
    cluster_axis: Optional[str] = None

    @property
    def is_sharded(self) -> bool:
        return self.mesh is not None

    # ------------------------------------------------------------------ #
    def sharding(self, axis: Optional[str] = None) -> Optional[NamedSharding]:
        """NamedSharding partitioning the leading dim over ``axis``
        (None = replicated).  Returns None on the single-device fallback."""
        if self.mesh is None:
            return None
        spec = PartitionSpec() if axis is None else PartitionSpec(axis)
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> Optional[NamedSharding]:
        return self.sharding(None)

    def group_axis(self, field: str) -> Optional[str]:
        if field in DEVICE_GROUP:
            return self.device_axis
        if field in CLUSTER_GROUP:
            return self.cluster_axis
        return None

    def state_shardings(self, state) -> Any:
        """A pytree of NamedShardings matching a `FleetState` (any NamedTuple
        whose field names follow the leaf-group convention)."""
        out = {}
        for field in state._fields:
            sh = self.sharding(self.group_axis(field))
            out[field] = jax.tree.map(lambda _: sh, getattr(state, field))
        return type(state)(**out)

    def tree_replicated(self, tree) -> Any:
        repl = self.replicated()
        return jax.tree.map(lambda _: repl, tree)

    def shard_state(self, state) -> Any:
        """Commit a FleetState's leaves to their group shardings."""
        if not self.is_sharded:
            return state
        return jax.device_put(state, self.state_shardings(state))


SINGLE_DEVICE = Placement()


def _mesh_devices(mesh_shape) -> np.ndarray:
    """The device array backing a mesh, or a readable error.  Spans *all*
    processes under `jax.distributed` (multi-controller SPMD)."""
    need = math.prod(mesh_shape)
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"sharding: mesh {tuple(mesh_shape)} needs {need} devices but "
            f"the {devices[0].platform} backend exposes {len(devices)}; on "
            "a CPU host, force a device pool with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    return np.asarray(devices[:need]).reshape(mesh_shape)


def resolve(sharding: ShardingSpec, *, n_devices: int, n_clusters: int,
            impl: Optional[str] = None) -> Placement:
    """`ShardingSpec` -> `Placement` over this process's visible devices.

    ``impl`` overrides the spec's resolved implementation for validation
    purposes — the plain `DeviceScaleEngine` passes ``'gspmd'`` so a
    shard_map-defaulted spec forced onto the fallback path still gets the
    strict divisibility check that path requires.

    Raises with a readable error when the mesh does not divide the fleet
    (``impl='gspmd'``; delegated to ``ShardingSpec.validate``) or needs
    more devices than the backend exposes.
    """
    if not sharding.is_sharded:
        return SINGLE_DEVICE
    if impl is not None and impl != sharding.resolved_impl():
        sharding = dataclasses.replace(sharding, impl=impl)
    sharding.validate(n_devices, n_clusters)
    axes = sharding.resolved_axes()
    mesh = Mesh(_mesh_devices(sharding.mesh), axes)
    return Placement(mesh=mesh, device_axis=sharding.device_axis,
                     cluster_axis=sharding.resolved_cluster_axis(axes))


def shard_map_placement(sharding: ShardingSpec) -> Placement:
    """The cluster-major placement: one 1-D mesh axis carrying *both* leaf
    groups (fleet rows are cluster-major, so device and cluster dims
    co-shard by construction).  Used by `repro.api.cluster_engine`."""
    assert sharding.is_sharded and len(sharding.mesh) == 1
    axes = sharding.resolved_axes()
    mesh = Mesh(_mesh_devices(sharding.mesh), axes)
    return Placement(mesh=mesh, device_axis=axes[0], cluster_axis=axes[0])
