"""Execution engines behind the `Federation` facade.

`DeviceScaleEngine` is the paper's §IV-D discrete-event simulator (formerly
the `AsyncFederation` monolith) with every policy choice delegated to a
pluggable component: the frequency controller picks a_i, the aggregator
folds member updates (Eqn 6 through the Pallas ``trust_aggregate`` kernel by
default), the task adapter owns the model, and the shared Eqn-19
`time_weighted_average` closes each global round.  The legacy
`AsyncFederation` entry point is a shim over this engine, so both entry
points produce identical traces at a fixed seed
(tests/test_api.py::test_spec_parity_with_legacy covers the shim's
config-translation path).

`DatacenterEngine` drives the sharded `fl_step` mode-A/B train steps under
the same controller protocol and emits the same `RoundRecord` trace.
"""
from __future__ import annotations

import heapq
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import cluster_devices, tolerance_bound
from repro.core.energy import (channel_transition, comm_energy,
                               compute_energy, step_channel)
from repro.core.trust import (belief, gradient_diversity, learning_quality,
                              time_weighted_average, trust_weights,
                              update_reputation)
from repro.core.twin import (TwinState, calibrate, calibrated_freq,
                             init_twins, observe_round, sample_deviation)

from .components import ControllerCtx
from .records import FLTrace, RoundRecord
from .spec import DEVICE_SCALE, FederationSpec


def _flatten_params(tree):
    return jnp.concatenate([x.reshape(x.shape[0], -1)
                            for x in jax.tree.leaves(tree)], axis=1)


class DeviceScaleEngine:
    """Discrete-event asynchronous clustered FL over a device fleet."""

    def __init__(self, spec: FederationSpec, data, parts, *,
                 controller, aggregator, task):
        assert spec.scale == DEVICE_SCALE
        self.spec = spec
        self.data = data
        self.parts = parts
        self.controller = controller
        self.aggregator = aggregator
        self.task = task

        key = jax.random.PRNGKey(spec.seed)
        (self.key, kt, kd, kc, kp, km) = jax.random.split(key, 6)
        self.twins = sample_deviation(
            kd, init_twins(kt, spec.fleet.n_devices), spec.fleet.dt_max_dev)
        sizes = jnp.asarray([len(p) for p in parts], jnp.float32)
        self.twins = self.twins._replace(data_size=sizes)
        self.assign, _ = cluster_devices(kc, self.twins,
                                         spec.clustering.n_clusters)
        self.assign = np.asarray(self.assign)
        self.global_params = task.init(kp, dim=data.x.shape[1])
        self.cluster_params = [self.global_params] * spec.clustering.n_clusters
        self.cluster_ts = np.zeros(spec.clustering.n_clusters)
        self.round = 0
        self.rep = jnp.ones((spec.fleet.n_devices,))
        self.channel = jnp.zeros((spec.fleet.n_devices,), jnp.int32)
        self.malicious = np.zeros(spec.fleet.n_devices, bool)
        n_mal = int(spec.fleet.malicious_frac * spec.fleet.n_devices)
        if n_mal:
            self.malicious[np.asarray(jax.random.choice(
                km, spec.fleet.n_devices, (n_mal,), replace=False))] = True
        self.energy_used = 0.0
        self.agg_count = 0

    # ---------------------------------------------------------------- #
    def _cluster_freq(self, c: int) -> float:
        members = np.where(self.assign == c)[0]
        f = np.asarray(calibrated_freq(self.twins))[members]
        return float(f.min()) if len(members) else 1.0

    def _obs(self, c: int) -> jnp.ndarray:
        """DQN observation (§IV-B layout, envs.OBS_DIM)."""
        from repro.core.envs import OBS_DIM
        members = self.assign == c
        loss = float(np.nan_to_num(
            np.asarray(self.twins.loss)[members].mean(), posinf=2.3))
        tau = float(self.task.hidden_mean(self.cluster_params[c],
                                          self.data.x[:256]))
        ch = np.asarray(jax.nn.one_hot(self.channel, 3).mean(0))
        feats = np.concatenate([
            [loss, 2.3 - loss, self.energy_used, self.round / 100.0, tau],
            np.eye(10)[min(9, self.agg_count % 10)], ch,
            [float(calibrated_freq(self.twins)[members].mean()), 0.0, 0.0]])
        return jnp.asarray(np.pad(feats, (0, OBS_DIM - len(feats))),
                           jnp.float32)

    def _ctx(self, c: int) -> ControllerCtx:
        members = self.assign == c
        loss = float(np.nan_to_num(
            np.asarray(self.twins.loss)[members].mean(), posinf=2.3))
        ch = np.asarray(self.channel)[members]
        return ControllerCtx(
            round=self.round, cluster=c, obs=lambda: self._obs(c),
            cluster_loss=loss, cluster_freq=self._cluster_freq(c),
            mean_freq=float(calibrated_freq(self.twins)[members].mean()),
            channel_good_frac=float((ch == 0).mean()) if len(ch) else 1.0,
            energy_used=self.energy_used)

    def _pick_frequency(self, c: int) -> int:
        """Controller choice capped by the Alg.-2 tolerance bound."""
        spec = self.spec
        a = self.controller.select(self._ctx(c))
        t_min = min(1.0 / max(self._cluster_freq(cc), 1e-6)
                    for cc in range(spec.clustering.n_clusters))
        alpha = min(1.0, spec.clustering.alpha0 +
                    spec.clustering.alpha_growth * self.round)
        a = int(tolerance_bound(jnp.asarray(a), jnp.asarray(
            self._cluster_freq(c)), jnp.asarray(t_min), alpha))
        return max(1, min(a, self.controller.n_actions))

    # ---------------------------------------------------------------- #
    def _cluster_round(self, c: int, a: int, kround):
        """One asynchronous cluster round: local training on every member,
        pluggable intra-cluster aggregation.  Returns sim duration."""
        spec = self.spec
        members = np.where(self.assign == c)[0]
        kb, ke, kc2 = jax.random.split(kround, 3)

        # --- local batches (possibly label-flipped for malicious nodes)
        xs, ys = [], []
        for m in members:
            ix = self.parts[m]
            sel = np.asarray(jax.random.choice(
                jax.random.fold_in(kb, int(m)), jnp.asarray(ix),
                (spec.local_batch,), replace=len(ix) < spec.local_batch))
            y = np.asarray(self.data.y)[sel]
            if self.malicious[m]:
                y = self.task.corrupt_labels(y)        # Byzantine label flip
            xs.append(np.asarray(self.data.x)[sel])
            ys.append(y)
        batch = {"x": jnp.asarray(np.stack(xs)),
                 "y": jnp.asarray(np.stack(ys))}

        # --- a local steps on every member (vmap), from the cluster model
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (len(members),) + x.shape),
            self.cluster_params[c])
        new = self.task.local_train(stacked, batch, spec.lr, a)

        # --- trust update (Eqns 4-5) & pluggable aggregation (Eqn 6)
        upd_flat = _flatten_params(new) - _flatten_params(stacked)
        q = learning_quality(upd_flat)
        div = gradient_diversity(upd_flat)
        tw_m = jax.tree.map(lambda x: x[members], self.twins._asdict())
        twins_m = TwinState(**tw_m)
        b = belief(twins_m, q, spec.channel.pkt_fail, div)
        rep_m = update_reputation(self.rep[members], b,
                                  spec.channel.pkt_fail, spec.iota)
        self.rep = self.rep.at[jnp.asarray(members)].set(rep_m)
        w = trust_weights(rep_m)
        agg = self.aggregator(new, w)
        if spec.privacy.clip > 0.0:
            from repro.core.privacy import dp_aggregate
            self.key, kdp = jax.random.split(self.key)
            uniform = jnp.full((len(members),), 1.0 / len(members))
            agg = dp_aggregate(
                kdp, new, self.cluster_params[c],
                w if spec.aggregator.kind == "trust" else uniform,
                spec.privacy.clip, spec.privacy.noise)
        self.cluster_params[c] = agg

        # --- losses, energy, twins
        losses = self.task.losses(new, batch)
        e_cmp = a * compute_energy(
            (self.twins.freq + self.twins.freq_dev)[members])
        e_com = comm_energy(self.channel[members], ke)
        consumed = float(e_cmp.sum() + e_com.sum())
        self.energy_used += consumed
        full_loss = self.twins.loss.at[jnp.asarray(members)].set(losses)
        full_e = jnp.zeros_like(self.twins.energy).at[
            jnp.asarray(members)].set(e_cmp + e_com)
        self.twins = observe_round(
            self.twins, full_loss, full_e,
            jnp.asarray(self.malicious, jnp.float32))
        if spec.fleet.calibrate_dt:
            self.twins = calibrate(self.twins)
        self.channel = step_channel(kc2, self.channel,
                                    channel_transition(spec.channel.p_good))
        self.controller.observe(None, consumed,
                                float(np.asarray(losses).mean()))
        return float(a) / max(self._cluster_freq(c), 1e-6)

    def _global_aggregate(self):
        """Eqn 19 via the one shared staleness-weighting implementation."""
        staleness = jnp.asarray(self.round - self.cluster_ts, jnp.float32)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *self.cluster_params)
        self.global_params, _ = time_weighted_average(stacked, staleness)
        self.agg_count += 1

    # ---------------------------------------------------------------- #
    def run(self, eval_every: float = 1.0) -> FLTrace:
        spec = self.spec
        trace = FLTrace()
        events = [(0.0, c) for c in range(spec.clustering.n_clusters)]
        heapq.heapify(events)
        t = 0.0
        next_eval = 0.0
        while events and t < spec.sim_seconds:
            t, c = heapq.heappop(events)
            if t >= spec.sim_seconds:
                break
            self.key, ka, kr = jax.random.split(self.key, 3)
            a = self._pick_frequency(c)
            dur = self._cluster_round(c, a, kr)
            self.round += 1
            self.cluster_ts[c] = self.round
            self._global_aggregate()
            # redistribute global model to the cluster (async pull)
            self.cluster_params[c] = self.global_params
            heapq.heappush(events, (t + dur, c))
            if t >= next_eval:
                m = self.task.evaluate(self.global_params, self.data)
                trace.append(RoundRecord(
                    t=t, round=self.round, cluster=c, a=a,
                    loss=m["loss"], acc=m.get("acc"),
                    energy=self.energy_used, agg_count=self.agg_count))
                next_eval = t + eval_every
        return trace


class DatacenterEngine:
    """Sharded fl_step (mode A/B) under the unified spec + trace schema.

    A smoke-scale driver of the datacenter path: the controller picks a_i
    per round exactly as at device scale (one pseudo-cluster ctx), trust
    reputations feed Eqn 6 inside the jit-ed step, staleness is zero
    (synchronous pods) unless the spec says otherwise.
    """

    def __init__(self, spec: FederationSpec, *, controller, task):
        from repro.core import fl_step
        from repro.optim import adam
        self.spec = spec
        self.controller = controller
        self.task = task
        self.n_clusters = spec.clustering.n_clusters
        self.clients = max(1, spec.fleet.n_devices // self.n_clusters)
        self.opt = adam(task.lr)
        init = fl_step.build_init_fn(
            task.cfg, self.opt, mode=task.mode,
            n_clusters=self.n_clusters, clients_per_cluster=self.clients)
        self.key = jax.random.PRNGKey(spec.seed)
        self.state = init(self.key)
        self.rep = jnp.ones((self.n_clusters, self.clients))
        self._steps = {}
        self._fl = fl_step

    def _step(self, a: int):
        if a not in self._steps:
            self._steps[a] = jax.jit(self._fl.build_train_step(
                self.task.cfg, self.opt, mode=self.task.mode, local_steps=a))
        return self._steps[a]

    def run(self, eval_every: float = 1.0) -> FLTrace:
        del eval_every                      # every round is recorded
        from repro.core.envs import OBS_DIM
        spec = self.spec
        trace = FLTrace()
        loss = float("nan")
        for i in range(spec.rounds):
            self.key, kb = jax.random.split(self.key)
            obs_feats = jnp.asarray([0.0 if np.isnan(loss) else loss,
                                     i / max(spec.rounds, 1), 0.0])
            ctx = ControllerCtx(
                round=i, cluster=0,
                obs=lambda f=obs_feats: jnp.pad(f, (0, OBS_DIM - 3)),
                cluster_loss=0.0 if np.isnan(loss) else loss,
                cluster_freq=1.0, mean_freq=1.0, channel_good_frac=1.0,
                energy_used=0.0)
            a = max(1, min(self.controller.select(ctx),
                           self.controller.n_actions))
            batch = self.task.make_batch(kb, self.n_clusters, self.clients)
            stale = jnp.zeros((self.n_clusters,))
            self.state, metrics = self._step(a)(
                self.state, batch, self.rep, stale)
            loss = float(jnp.mean(metrics["loss"]))
            # no energy model at datacenter scale: report zero consumption
            # (a raw step count would corrupt a Lyapunov queue's units)
            self.controller.observe(ctx, 0.0, loss)
            trace.append(RoundRecord(
                t=float(i), round=i + 1, cluster=-1, a=a, loss=loss,
                acc=None, energy=0.0, agg_count=i + 1))
        return trace
