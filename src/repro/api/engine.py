"""Execution engines behind the `Federation` facade.

The engine contract is explicit: the `Engine` protocol below (a
``from_spec`` classmethod taking the spec plus built component instances,
``run``, ``run_scanned``, one `FLTrace`/`RoundRecord` schema), and engines
register under `repro.api.registry.ENGINES` keyed by ``spec.scale`` —
`Federation` resolves the scale like any other component.

*Where* an engine runs is spec data too: `DeviceScaleEngine` resolves
``spec.sharding`` through `repro.api.placement` and, when a mesh is
present, commits the initial `FleetState` to its leaf-group shardings and
pins jit ``in_shardings``/``out_shardings`` on both the per-event fused
round and the whole ``run_scanned`` scan (device leaves over the fleet
axis, cluster stack + event times over the cluster axis, scalars/global
model replicated).  The single-device default builds exactly the
pre-placement jits.

`DeviceScaleEngine` is the paper's §IV-D discrete-event simulator rebuilt
around an immutable **`FleetState`** struct-of-arrays pytree: twins,
reputation, channel, stacked per-cluster parameters, energy, the global
model, and the RNG key all live in one donated device-resident structure.
Each asynchronous cluster round — batch gather from a precomputed padded
partition matrix, vmapped local training, the Eqn 4-5 belief/reputation
update, Eqn-6 aggregation through the masked Pallas ``trust_aggregate``
kernel, the optional DP path, energy accounting (Eqns 7-8), the twin
observe/calibrate step, and the Eqn-19 staleness-weighted global average —
is **one fused jit-compiled call** `_fleet_round(state, c, a, members,
mask)`.  Only the event heap, the controller's `select`, evaluation, and
the float64 cumulative-energy tally stay on the host: a single 4-scalar
metrics dict (bounded a, round duration, consumed energy, mean loss)
crosses the device boundary per round.

Ragged cluster memberships run as fixed-shape grids: mask-aware
aggregators (``supports_mask=True``, i.e. trust/fedavg) share one compiled
round over a (n_clusters, M) padded membership table whose padding slots
hold an out-of-range sentinel (gathers fill, scatters drop).  Aggregators
built on rank statistics (krum, median, ...) cannot ignore padded rows, so
the engine compiles one exact-shape round per distinct cluster size
instead — same function, shape-specialized by jit's cache.

The control plane is device-resident too (`repro.control`): the Eqn-12
Lyapunov deficit queue lives in `FleetState` as an array leaf advanced
in-jit with the realized consumption, and every built-in controller exposes
a scannable `(state, CtlObs) -> (action, state)` policy.  ``run_scanned(K)``
lowers K whole rounds — cluster scheduling by argmin over a carried
per-cluster event-time vector (reproducing the heap's (t, c) order),
in-jit `select`, the fused round, and the queue update — into a **single
`lax.scan`**; per-round metrics are stacked on device and synced once at
the end, where the float64 cumulative-energy tally is rebuilt from the
stacked f32 consumptions by the same sequential f64 additions the event
loop performs (device f64 is unavailable with x64 disabled, and this is
bitwise identical to accumulating a f64 leaf in the scan carry).  One
accumulation does differ: the scan carries per-cluster event times in f32
where the heap sums f64 Python floats, so two clusters whose next-event
times fall within f32 rounding of each other could in principle be popped
in a different order — at the tested seeds and scales the traces match
bit-for-bit on scheduling and counters, but sub-ulp event-time ties are
not ordered identically by construction.  The
event-heap path remains for ragged schedules (``sim_seconds`` cutoffs,
per-round evaluation) and exact-shape robust aggregators.

``fused=False`` runs the *identical* round function eagerly (op-by-op
dispatch with per-round host syncs) — the pre-refactor execution profile.
Fused and reference modes consume the same RNG streams and the same
fixed-shape math, so their traces match at a fixed seed — bit for bit on
scheduling, counters and accuracies; to the last ulp on float reductions,
where XLA's fused (FMA-contracted) form may differ from eager dispatch
(tests/test_api.py::test_fused_round_parity_with_reference) — and
benchmarks/engine_bench.py measures the fusion speedup between them.
One *statistical* change from the pre-refactor engine: batches are always
sampled with replacement (`sample_member_batch`'s fixed-shape randint);
the old per-member loop sampled without replacement when a shard held at
least ``local_batch`` examples.

The legacy `AsyncFederation` entry point is a shim over this engine, so
both entry points produce identical traces at a fixed seed
(tests/test_api.py::test_spec_parity_with_legacy covers the shim's
config-translation path).

`DatacenterEngine` drives the sharded `fl_step` mode-A/B train steps under
the same controller protocol and emits the same `RoundRecord` trace.
"""
from __future__ import annotations

import contextlib
import heapq
from typing import (Any, NamedTuple, Optional, Protocol, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.control import policy as ctl_policy
from repro.control import queue as ctl_queue
from repro.core.clustering import (cluster_devices, ensure_nonempty,
                                   padded_membership, tolerance_bound)
from repro.core.energy import channel_transition, round_energy, step_channel
from repro.core.envs import OBS_DIM
from repro.core.trust import (belief, gradient_diversity, learning_quality,
                              staleness_weights, time_weighted_average,
                              trust_weights, update_reputation)
from repro.core.twin import (calibrate, calibrated_freq, init_twins,
                             member_view, observe_round_members,
                             sample_deviation, TwinState)
from repro.data.federated import padded_partition, sample_member_batch
from repro.faults import FaultModel

from . import placement as placement_lib
from .components import ControllerCtx
from .records import FLTrace, RoundRecord
from .registry import register_engine
from .spec import (DATACENTER_SCALE, DEVICE_SCALE, FederationSpec,
                   SHARD_MAP_IMPL)

# the jit-sharded GSPMD path stays registry-selectable under its own scale
# (`DeviceScaleEngine.from_spec` also routes back to it via
# ``ShardingSpec.impl='gspmd'``)
GSPMD_DEVICE_SCALE = "device-gspmd"


def _flatten_params(tree):
    return jnp.concatenate([x.reshape(x.shape[0], -1)
                            for x in jax.tree.leaves(tree)], axis=1)


@runtime_checkable
class Engine(Protocol):
    """The execution-engine contract behind `Federation`.

    An engine registers under `repro.api.registry.ENGINES` keyed by
    ``FederationSpec.scale`` and provides:

      from_spec   classmethod constructor taking the spec plus built
                  component instances (``controller``/``aggregator``/
                  ``task``) and the optional ``data``/``parts``/``fused``
                  overrides; engines that generate their own data ignore
                  the overrides they don't consume.
      run         the engine's native loop; emits the `FLTrace` /
                  `RoundRecord` schema shared by every scale.
      run_scanned exactly-K-rounds lowering with end-of-run metrics sync;
                  engines without one raise ValueError with a pointer to
                  ``run``.

    `Federation` resolves ``spec.scale`` through the registry and calls
    only this surface — adding a scale is a registration, not a facade
    edit.
    """

    spec: FederationSpec

    @classmethod
    def from_spec(cls, spec: FederationSpec, *, controller, aggregator,
                  task, data=None, parts=None,
                  fused: Optional[bool] = None) -> "Engine":
        ...

    def run(self, eval_every: float = 1.0,
            max_rounds: Optional[int] = None) -> FLTrace:
        ...

    def run_scanned(self, K: int, *, eval_final: bool = True) -> FLTrace:
        ...


class FleetState(NamedTuple):
    """Struct-of-arrays state of the whole federation, one jit-donatable
    pytree.  Leaves are device arrays; the only host-side state the engine
    keeps beside this is the event heap, the round counter mirror, and the
    float64 cumulative-energy accumulator (per-device energies live in
    ``twins.energy``)."""
    twins: TwinState            # per-device digital twins (SoA over fleet)
    rep: jnp.ndarray            # (n,)  Eqn-5 reputations
    channel: jnp.ndarray        # (n,)  Markov channel state, int32
    cluster_params: Any         # pytree, leaves (n_clusters, ...)
    global_params: Any          # pytree, leaves (...): Eqn-19 aggregate
    cluster_ts: jnp.ndarray     # (n_clusters,) last-update round, f32
    queue: jnp.ndarray          # ()  Eqn-12 Lyapunov deficit backlog, f32
    round: jnp.ndarray          # ()  global round counter, int32
    key: jnp.ndarray            # typed PRNG key (jax.random.key) driving
                                # every round's randomness; repro.checkpoint
                                # round-trips it via its __key__: marker


class DeviceScaleEngine:
    """Discrete-event asynchronous clustered FL over a device fleet."""

    def __init__(self, spec: FederationSpec, data, parts, *,
                 controller, aggregator, task,
                 fused: Optional[bool] = None, assign=None):
        assert spec.scale in (DEVICE_SCALE, GSPMD_DEVICE_SCALE)
        self.spec = spec
        self.data = data
        self.parts = parts
        self.controller = controller
        self.aggregator = aggregator
        self.task = task
        # where the fleet lives: a jax.sharding mesh resolved from the
        # spec, or the single-device fallback (shardings all None).  This
        # engine is the jit-sharded GSPMD path, so the placement validates
        # under that impl's (stricter, divisible) rules even when the spec
        # resolves to shard_map by default.
        self.placement = placement_lib.resolve(
            spec.sharding, n_devices=spec.fleet.n_devices,
            n_clusters=spec.clustering.n_clusters, impl="gspmd")

        n = spec.fleet.n_devices
        C = spec.clustering.n_clusters
        # typed key (not the legacy raw uint32 pair): same threefry bits,
        # but the dtype survives a checkpoint round-trip as a key
        key = jax.random.key(spec.seed)
        key0, kt, kd, kc, kp, km = jax.random.split(key, 6)
        twins = sample_deviation(kd, init_twins(kt, n), spec.fleet.dt_max_dev)
        sizes = jnp.asarray([len(p) for p in parts], jnp.float32)
        twins = twins._replace(data_size=sizes)
        if assign is None:
            # kc is always split so an assignment override (capacity
            # benchmarks skip the O(n*C) k-means) leaves every other
            # stream in the engine untouched
            assign, _ = cluster_devices(kc, twins, C)
        self.assign = ensure_nonempty(np.asarray(assign), C)
        self._member_table, self._member_mask = padded_membership(
            self.assign, C)

        self.malicious = np.zeros(n, bool)
        n_mal = int(spec.fleet.malicious_frac * n)
        if n_mal:
            self.malicious[np.asarray(jax.random.choice(
                km, n, (n_mal,), replace=False))] = True
        self._malicious_dev = jnp.asarray(self.malicious, jnp.float32)

        # declarative fault injection (spec.faults -> pure-jnp round
        # transforms); the default spec is inert and the gating below is
        # *static*, so fault-free runs compile the exact pre-fault round
        self.faults = FaultModel(spec.faults, n)
        self._sentinel = jnp.int32(n)   # padded-membership fill index
        # the Eqn-4 interaction tallies treat the fault model's static
        # Byzantine subsets exactly like the label-flip attackers: each
        # round a misbehaving member's beta count grows, so reputation —
        # not just the per-round FoolsGold signals — learns persistent
        # attackers (inert spec: both subsets are zero, nothing changes)
        self._misbehaving_dev = jnp.maximum(
            self._malicious_dev,
            jnp.maximum(self.faults.corrupt_dev, self.faults.poison_dev))

        gp = task.init(kp, dim=data.x.shape[1])
        cparams = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (C,) + l.shape) + 0.0, gp)
        self.state = self.placement.shard_state(FleetState(
            twins=twins, rep=jnp.ones((n,)),
            channel=jnp.zeros((n,), jnp.int32),
            cluster_params=cparams, global_params=gp,
            cluster_ts=jnp.zeros((C,), jnp.float32),
            queue=ctl_queue.init_leaf(),
            round=jnp.zeros((), jnp.int32), key=key0))
        # Eqn-12 replenishment rate of the controller's deficit queue
        # (+inf for budgetless controllers: the queue leaf stays 0)
        self._queue_per_slot = ctl_queue.per_slot_of(controller)

        # static fleet tables consumed by the fused round
        self._x = jnp.asarray(data.x)
        self._y = jnp.asarray(data.y)
        self._part_idx, self._part_len = padded_partition(parts)
        self._trans = channel_transition(spec.channel.p_good)
        self._n_actions = int(getattr(controller, "n_actions", 10))
        self._needs_ctx = bool(getattr(controller, "needs_ctx", True))
        # mask-aware aggregators share one padded fixed-shape compilation;
        # rank-statistic rules get exact member shapes (one compile per size)
        self._padded = bool(getattr(aggregator, "supports_mask", False))
        if self._padded:
            self._members = [self._member_table[c] for c in range(C)]
            self._masks = [self._member_mask[c] for c in range(C)]
        else:
            self._members = [jnp.asarray(np.where(self.assign == c)[0],
                                         jnp.int32) for c in range(C)]
            self._masks = [jnp.ones((len(g),), bool) for g in self._members]

        # aggregators exposing the fused Eqn-6+19 kernel path
        # (`aggregate_with_global`) fold the global average into the same
        # pass when the round is padded and DP is off
        self._fused_global = self._padded and bool(
            getattr(aggregator, "supports_fused_global", False))

        self.fused = True if fused is None else bool(fused)
        # donate the FleetState buffers so the round updates in place
        # (CPU ignores donation and warns, so only request it elsewhere)
        donate = (0,) if jax.default_backend() != "cpu" else ()
        jit_kw = dict(donate_argnums=donate)
        if self.placement.is_sharded:
            # pin the round's output placement so the FleetState carry keeps
            # its leaf-group shardings instead of drifting to whatever the
            # SPMD partitioner last inferred; the 4 metrics scalars replicate
            repl = self.placement.replicated()
            jit_kw["out_shardings"] = (
                self.placement.state_shardings(self.state),
                {"a": repl, "dur": repl, "consumed": repl, "loss": repl})
        self._round_fn = (
            jax.jit(self._fleet_round, **jit_kw)
            if self.fused else self._fleet_round)
        self._rounds = 0
        # cumulative energy accumulates host-side in float64 (the per-round
        # `consumed` scalar crosses to the host anyway); a float32 device
        # accumulator would drop sub-ulp additions on long simulations
        self._energy_used = 0.0
        # sink-less scanned segments defer that sync: per-segment consumed
        # stacks queue device-side in `_pending` and the f32 tally carries
        # in `_energy_dev` until something host-visible (a trace, the
        # energy_used property, a checkpoint) flushes them
        self._pending = []
        self._energy_dev = jnp.float32(0.0)
        # per-cluster event times carried *across* run_scanned calls, so
        # run_scanned(K) twice continues exactly where run_scanned(2K)
        # would be — the invariant the checkpointed service mode
        # (`repro.serve`) resumes on.  The round counter and energy tally
        # already carried; this makes the schedule carry too.
        self._scan_times = jnp.zeros((C,), jnp.float32)
        # optional streaming tap for emitted traces (`repro.serve` points
        # this at a JSONL file); None = the in-memory batch default
        self.trace_sink = None
        self.trace_retain = True
        # optional telemetry bundle (`repro.obs.EngineObs`): metrics
        # registry + span recorder.  Attached via `set_obs`; everything it
        # feeds on either already crosses the host boundary (the stacked
        # per-segment metrics, the event loop's per-round dict) or is a
        # separate read-only jitted reduction — never a change to the
        # round program, so traces stay bit-identical with it attached
        self.obs = None
        self._obs_summary_fn = None
        # control plane: jitted host ctx features / observation builders
        # + compiled scan paths
        self._features_fn = jax.jit(self._ctl_features)
        self._obs_fn = jax.jit(lambda state, c: self._scan_obs(
            state, c, self._ctl_features(state, c)))
        self._scan_cache = {}       # K -> compiled lax.scan-over-rounds

    # ------------------------------------------------------------------ #
    @classmethod
    def from_spec(cls, spec: FederationSpec, *, controller, aggregator,
                  task, data=None, parts=None,
                  fused: Optional[bool] = None,
                  assign=None) -> "DeviceScaleEngine":
        if data is None or parts is None:
            data, parts = default_device_data(spec)
        # 1-D meshes default to the cluster-major shard_map engine (the
        # membership-local path); impl='gspmd' or the 'device-gspmd' scale
        # keeps the jit-sharded fallback.  `cls is` so the subclasses
        # (gspmd pin, cluster-major itself) never re-dispatch.
        if (cls is DeviceScaleEngine and spec.sharding.is_sharded
                and spec.sharding.resolved_impl() == SHARD_MAP_IMPL):
            from .cluster_engine import ClusterMajorEngine
            return ClusterMajorEngine(
                spec, data, parts, controller=controller,
                aggregator=aggregator, task=task, fused=fused,
                assign=assign)
        return cls(spec, data, parts, controller=controller,
                   aggregator=aggregator, task=task, fused=fused,
                   assign=assign)

    # ------------------------------------------------------------------ #
    # streamed traces + resumable state (the `repro.serve` surface)
    # ------------------------------------------------------------------ #
    def set_trace_sink(self, sink, *, retain: bool = True) -> None:
        """Stream every emitted `RoundRecord` to ``sink`` (an object with
        ``append(RoundRecord)``, e.g. `repro.api.records.JsonlSink`).
        ``retain=False`` stops the trace from also accumulating records in
        memory — required for unbounded service runs."""
        self.trace_sink = sink
        self.trace_retain = bool(retain)

    def _new_trace(self) -> FLTrace:
        return FLTrace(sink=self.trace_sink, retain=self.trace_retain)

    # telemetry (`repro.obs` — see API.md "Observability") -------------- #
    def set_obs(self, obs) -> None:
        """Attach an `repro.obs.EngineObs` telemetry bundle (``None``
        detaches).  The engine publishes per-segment round aggregates,
        state summaries, compile events, and fault tallies into it.
        Attaching telemetry never alters the compiled round program —
        emitted traces stay bit-identical to an uninstrumented run
        (pinned by tests/test_obs.py)."""
        self.obs = obs
        if obs is not None:
            obs.publish_static(self)

    def _obs_span(self, name: str, fence_on=None, **attrs):
        if self.obs is None:
            return contextlib.nullcontext()
        return self.obs.span(name, fence_on=fence_on, **attrs)

    def _instrument_compile(self, name: str, fn, args):
        """Compile ``fn`` for ``args`` under telemetry.

        With no obs attached, returns ``fn`` unchanged (the plain jit
        path — compilation happens implicitly on first call, exactly as
        before).  Under telemetry, lower+compile explicitly (AOT builds
        the *same* executable the first jit call would) inside a
        ``span("compile")``, and feed the optimized HLO through
        `hlo_stats.analyze_module` for the one-time compile event."""
        if self.obs is None:
            return fn
        with self.obs.span("compile", fn=name) as sp:
            compiled = fn.lower(*args).compile()
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = None
        self.obs.record_compile(name, sp.dur_s, hlo)
        return compiled

    def obs_state_summary(self) -> dict:
        """Host scalars for the telemetry gauges: Eqn-12 deficit-queue
        level, Eqn-4 trust-weight (reputation) summary stats, and the
        fleet's total β (negative-interaction) tally.  One read-only
        jitted reduction over `FleetState` — never part of the round
        program, so sampling it cannot perturb compiled math."""
        if self._obs_summary_fn is None:
            def summarize(state):
                rep = state.rep
                return {"queue_deficit": state.queue,
                        "reputation_min": rep.min(),
                        "reputation_mean": rep.mean(),
                        "reputation_max": rep.max(),
                        "twin_beta_sum": state.twins.beta.sum()}
            self._obs_summary_fn = jax.jit(summarize)
        out = jax.device_get(self._obs_summary_fn(self.state))
        return {k: float(v) for k, v in out.items()}

    @property
    def scan_times(self) -> jnp.ndarray:
        """The carried per-cluster next-event times of the scanned path."""
        return self._scan_times

    def resumable_state(self) -> dict:
        """Everything device-resident a resumed run needs, as one
        checkpointable pytree: the full `FleetState` (including the RNG-key
        leaf and the Eqn-12 queue) plus the carried per-cluster event
        times.  Host-side scalars (round counter, f64 energy tally) ride in
        the checkpoint manifest instead — f64 would not survive an f32
        npz/jnp round-trip with x64 disabled."""
        self._flush_pending()           # manifest energy must be exact
        return {"fleet": self.state, "times": self._scan_times}

    def restore_resumable(self, tree: dict, *, rounds: int,
                          energy: float) -> None:
        """Adopt a `resumable_state` pytree (typically loaded through
        `repro.checkpoint`) plus the manifest scalars.  The engine must
        have been built from the same spec (assignments, partitions and the
        malicious mask are all deterministic in the spec seed, so a fresh
        process reconstructs them bit-identically)."""
        self.state = self.placement.shard_state(tree["fleet"])
        self._scan_times = jnp.asarray(tree["times"], jnp.float32)
        self._rounds = int(rounds)
        self._energy_used = float(energy)
        self._pending = []
        self._energy_dev = jnp.float32(energy)
        sync_queue = getattr(self.controller, "sync_queue", None)
        if sync_queue is not None:      # host controller adopts the
            sync_queue(self.state.queue)  # restored Eqn-12 backlog

    # ------------------------------------------------------------------ #
    # the fused round: everything below runs inside one jit call
    # ------------------------------------------------------------------ #
    def _cluster_freq_table(self, twins) -> jnp.ndarray:
        """Straggler (min) calibrated frequency of every cluster, (C,).
        One masked reduction over the padded membership table per call —
        the old engine recomputed the full-fleet `calibrated_freq` O(C^2)
        times per frequency pick."""
        f = calibrated_freq(twins)
        fmat = f.at[self._member_table].get(mode="fill",
                                            fill_value=jnp.inf)
        fmin = jnp.min(jnp.where(self._member_mask, fmat, jnp.inf), axis=1)
        return jnp.where(self._member_mask.any(axis=1), fmin, 1.0)

    def _fleet_round(self, state: FleetState, c, a_raw, members, mask):
        """One asynchronous cluster round (paper §IV-D), state -> state.

        Fuses: Alg.-2 tolerance bound, padded batch gather, vmapped local
        SGD, Eqns 4-5 trust, Eqn-6 aggregation (masked Pallas kernel),
        optional DP, Eqns 7-8 energy, twin observe/calibrate, channel step,
        and the Eqn-19 global aggregate.  ``members``/``mask`` are a
        fixed-shape member slice (padded with the sentinel n, or exact)."""
        spec = self.spec
        task = self.task
        fm = self.faults
        twins = state.twins
        # an active fault model splits one extra key; inert specs keep the
        # exact pre-fault stream (and compile the exact pre-fault program —
        # every fm.may_* gate below is a static Python bool)
        if fm.active:
            key, kb, ke, kc2, kdp, kflt = jax.random.split(state.key, 6)
        else:
            key, kb, ke, kc2, kdp = jax.random.split(state.key, 5)
            kflt = None
        if fm.may_drop:
            # dropped members leave the padded mask AND become the padding
            # sentinel, so every downstream gather fills neutrally and
            # every scatter (reputation, twin observe) drops them — the
            # round treats a dropped device exactly like a padding slot
            mask = fm.drop_mask(kflt, mask, members)
            members = jnp.where(mask, members, self._sentinel)
        mask_f = mask.astype(jnp.float32)
        cnt = jnp.maximum(jnp.sum(mask_f), 1.0)
        # a fully-dropped cluster skips its event: state carries unchanged
        # (the degenerate all-padding aggregate would zero the cluster row)
        empty = jnp.sum(mask_f) < 0.5 if fm.may_drop else None

        # --- controller choice capped by the Alg.-2 tolerance bound.
        # T_m is the fastest cluster's time for the *requested* local phase
        # (a_req / f_max, the convention test_tolerance_bound_caps_slow_
        # clusters pins); slower clusters get proportionally fewer steps,
        # scaling in as alpha grows.  The old reference (one step of the
        # fastest cluster) made the cap floor to 1 for every cluster at
        # alpha <= 1, silencing every frequency controller.
        cluster_freq = self._cluster_freq_table(twins)
        a_req = jnp.clip(jnp.asarray(a_raw), 1, self._n_actions)
        t_ref = a_req.astype(jnp.float32) / jnp.maximum(
            jnp.max(cluster_freq), 1e-6)
        alpha = jnp.minimum(
            1.0, spec.clustering.alpha0 +
            spec.clustering.alpha_growth * state.round.astype(jnp.float32))
        a = tolerance_bound(a_req, cluster_freq[c], t_ref, alpha)
        a = jnp.clip(a, 1, self._n_actions)

        # --- local batches from the padded partition matrix
        sel = sample_member_batch(kb, self._part_idx, self._part_len,
                                  members, spec.local_batch)
        x = self._x[sel]
        y = self._y[sel]
        if fm.may_poison:
            # poisons the sampled features before they enter local_train;
            # for reconstruction tasks (corrupt_labels a no-op) this is the
            # only attack surface that touches the loss
            x = fm.poison_inputs(kflt, x, members)
        mal_m = self._malicious_dev.at[members].get(mode="fill",
                                                    fill_value=0.0)
        y = jnp.where(mal_m[:, None] > 0.5, task.corrupt_labels(y), y)
        batch = {"x": x, "y": y}

        # --- a local steps on every member (vmap), from the cluster model
        m_dim = members.shape[0]
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l[c], (m_dim,) + l.shape[1:]),
            state.cluster_params)
        new = task.local_train(stacked, batch, spec.lr, a)
        if fm.may_corrupt:
            # Byzantine members replace their honest deltas *before* the
            # trust chain sees them — Eqns 4-5 must earn their keep
            new = fm.corrupt_updates(kflt, new, stacked, members)

        # --- trust update (Eqns 4-5) & pluggable aggregation (Eqn 6)
        upd_flat = _flatten_params(new) - _flatten_params(stacked)
        q = learning_quality(upd_flat, mask)
        div = gradient_diversity(upd_flat, mask)
        tw_m = member_view(twins, members)
        if fm.may_spike:
            # amplified f̂ deviation feeds straight into Eqn 4's
            # 1/(1+|Δf̂|) normalization
            tw_m = fm.spike_twins(kflt, tw_m, mask, members)
        b = belief(tw_m, q, spec.channel.pkt_fail, div)
        rep_m = update_reputation(
            state.rep.at[members].get(mode="fill", fill_value=1.0), b,
            spec.channel.pkt_fail, spec.iota)
        rep = state.rep.at[members].set(rep_m, mode="drop")
        w = trust_weights(rep_m, mask)
        # with a fused-global aggregator the Eqn-6 aggregate never leaves
        # the kernel (see the Eqn-19 block below); DP needs the bare
        # aggregate to clip against, so it keeps the two-step path
        fuse_global = self._fused_global and spec.privacy.clip <= 0.0
        if not fuse_global:
            agg = (self.aggregator(new, w, mask) if self._padded
                   else self.aggregator(new, w))
            if spec.privacy.clip > 0.0:
                from repro.core.privacy import dp_aggregate
                cur = jax.tree.map(lambda l: l[c], state.cluster_params)
                agg = dp_aggregate(
                    kdp, new, cur,
                    w if spec.aggregator.kind == "trust" else mask_f / cnt,
                    spec.privacy.clip, spec.privacy.noise, n_clients=cnt)
            cparams = jax.tree.map(
                lambda L, g: L.at[c].set(g.astype(L.dtype)),
                state.cluster_params, agg)

        # --- losses, energy (Eqns 7-8), twins
        losses = task.losses(new, batch)
        true_freq = (twins.freq + twins.freq_dev).at[members].get(
            mode="fill", fill_value=1.0)
        ch_m = state.channel.at[members].get(mode="fill", fill_value=0)
        e = round_energy(a.astype(jnp.float32), true_freq, ch_m, ke,
                         members=members) * mask_f
        consumed = jnp.sum(e)
        twins = observe_round_members(twins, members, losses, e,
                                      self._misbehaving_dev)
        if spec.fleet.calibrate_dt:
            twins = calibrate(twins)
        channel = step_channel(kc2, state.channel, self._trans)

        # --- Eqn 19: staleness-weighted global aggregate (async pull)
        rnd = state.round + 1
        ts = state.cluster_ts.at[c].set(rnd.astype(jnp.float32))
        if fuse_global:
            # one kernel pass: Eqn-6 reduction of the member updates +
            # substitution into the cluster stack + the Eqn-19 average
            # ((n_clusters + C, BLOCK) tiles per grid step; the per-shard
            # unit under a mesh placement)
            gparams = self.aggregator.aggregate_with_global(
                new, w, mask, state.cluster_params,
                staleness_weights(rnd.astype(jnp.float32) - ts), c)
            cparams = state.cluster_params
        else:
            gparams, _ = time_weighted_average(cparams,
                                               rnd.astype(jnp.float32) - ts)
        cparams = jax.tree.map(lambda L, g: L.at[c].set(g.astype(L.dtype)),
                               cparams, gparams)

        if fm.may_drop:
            # graceful degradation: a fully-dropped cluster spends nothing
            # and leaves every model/trust/twin leaf untouched — only the
            # RNG key, channel, and round counter advance, so the scheduler
            # re-enqueues the cluster instead of writing a zeroed aggregate
            revert = lambda old, newv: jax.tree.map(
                lambda o, v: jnp.where(empty, o, v), old, newv)
            consumed = jnp.where(empty, 0.0, consumed)
            twins = revert(state.twins, twins)
            rep = revert(state.rep, rep)
            cparams = revert(state.cluster_params, cparams)
            gparams = revert(state.global_params, gparams)
            ts = revert(state.cluster_ts, ts)

        # --- Eqn 12: the deficit queue advances in-jit with the realized
        # consumption (budgetless controllers have per_slot=inf -> q = 0)
        queue = ctl_queue.advance(state.queue, consumed,
                                  self._queue_per_slot)

        # --- round duration from the *post-calibration* straggler freq
        dur = a.astype(jnp.float32) / jnp.maximum(
            self._cluster_freq_table(twins)[c], 1e-6)
        if fm.may_straggle:
            dur = fm.straggle(kflt, dur, mask, members)

        new_state = FleetState(
            twins=twins, rep=rep, channel=channel, cluster_params=cparams,
            global_params=gparams, cluster_ts=ts, queue=queue, round=rnd,
            key=key)
        metrics = {"a": a, "dur": dur, "consumed": consumed,
                   "loss": jnp.sum(losses * mask_f) / cnt}
        return new_state, metrics

    # ------------------------------------------------------------------ #
    # control plane: per-cluster controller features, computable in-jit
    # ------------------------------------------------------------------ #
    def _ctl_features(self, state: FleetState, c):
        """The f32 scalars a frequency controller scores from, as pure jnp
        over the padded membership row of cluster ``c``.

        Both execution paths consume this one function — the event loop
        through the jitted ``self._features_fn`` (4 scalars pulled per
        round), the scanned path traced straight into the round scan — so
        host and in-jit ``select`` see identical device math.
        """
        twins = state.twins
        members = self._member_table[c]
        mask = self._member_mask[c]
        mask_f = mask.astype(jnp.float32)
        cnt = jnp.maximum(jnp.sum(mask_f), 1.0)

        loss_m = twins.loss.at[members].get(mode="fill", fill_value=0.0)
        loss = jnp.sum(jnp.where(mask, loss_m, 0.0)) / cnt
        loss = jnp.nan_to_num(loss, nan=0.0, posinf=2.3)
        f_m = calibrated_freq(twins).at[members].get(mode="fill",
                                                     fill_value=0.0)
        mean_freq = jnp.sum(jnp.where(mask, f_m, 0.0)) / cnt
        ch_m = state.channel.at[members].get(mode="fill", fill_value=1)
        good = jnp.sum(jnp.where(mask, (ch_m == 0).astype(jnp.float32),
                                 0.0)) / cnt
        return {"cluster_loss": loss, "mean_freq": mean_freq,
                "channel_good_frac": good,
                "cluster_freq": self._cluster_freq_table(twins)[c]}

    def _scan_obs(self, state: FleetState, c, feats) -> jnp.ndarray:
        """The §IV-B DQN observation, pure jnp — one layout for both the
        host path (`_obs`) and the round scan.

        Slot 2 carries the Eqn-12 deficit backlog off `FleetState.queue`,
        matching the env the agent trained on (`envs._obs`; it used to hold
        the unbounded energy tally, far outside the training range).  Known
        deployment deviations from the env layout remain: the one-hot
        encodes round%10 rather than the last action, and the spent/budget
        fraction (slot 4) is not observable fleet-side — tau stands in.
        """
        tau = self.task.hidden_mean(
            jax.tree.map(lambda l: l[c], state.cluster_params),
            self._x[:256])
        return ctl_policy.deploy_obs(
            feats["cluster_loss"], state.queue,
            state.round.astype(jnp.float32) / 100.0, tau,
            state.round % 10, jax.nn.one_hot(state.channel, 3).mean(0),
            feats["mean_freq"])

    # ------------------------------------------------------------------ #
    # host side: controller context
    # ------------------------------------------------------------------ #
    def _obs(self, c: int) -> jnp.ndarray:
        """DQN observation for host-side `select`: the same `_scan_obs`
        function the scanned path traces, as one jitted call."""
        return self._obs_fn(self.state, jnp.int32(c))

    def _ctx(self, c: int) -> ControllerCtx:
        f = jax.device_get(self._features_fn(self.state, jnp.int32(c)))
        return ControllerCtx(
            round=self._rounds, cluster=c, obs=lambda: self._obs(c),
            cluster_loss=float(f["cluster_loss"]),
            cluster_freq=float(f["cluster_freq"]),
            mean_freq=float(f["mean_freq"]),
            channel_good_frac=float(f["channel_good_frac"]),
            energy_used=self._energy_used)

    def _null_ctx(self, c: int) -> ControllerCtx:
        """Sync-free ctx for ``needs_ctx=False`` controllers; obs stays
        lazily available should a controller reach for it anyway."""
        return ControllerCtx(
            round=self._rounds, cluster=c, obs=lambda: self._obs(c),
            cluster_loss=0.0, cluster_freq=1.0, mean_freq=1.0,
            channel_good_frac=1.0, energy_used=0.0)

    # ------------------------------------------------------------------ #
    # scan-over-rounds: K rounds + in-jit controller in one lax.scan
    # ------------------------------------------------------------------ #
    def _build_scan_fn(self, K: int, pol: ctl_policy.ScanPolicy):
        def body(carry, _):
            state, times, ctl, energy = carry
            # the event heap pops min (t, c); argmin breaks ties on the
            # first (lowest) cluster index exactly as tuple order does
            c = jnp.argmin(times).astype(jnp.int32)
            t = times[c]
            feats = self._ctl_features(state, c)
            obs48 = (self._scan_obs(state, c, feats)
                     if pol.needs_obs else jnp.zeros((OBS_DIM,),
                                                     jnp.float32))
            cobs = ctl_policy.CtlObs(
                round=state.round, cluster=c, queue=state.queue,
                cluster_loss=feats["cluster_loss"],
                cluster_freq=feats["cluster_freq"],
                mean_freq=feats["mean_freq"],
                channel_good_frac=feats["channel_good_frac"],
                energy_used=energy, dqn_obs=obs48)
            a_raw, ctl = pol.step(ctl, cobs)
            state, m = self._fleet_round(
                state, c, a_raw, self._member_table[c],
                self._member_mask[c])
            times = times.at[c].set(t + m["dur"])
            energy = energy + m["consumed"]
            ys = {"t": t, "cluster": c, "a": m["a"], "dur": m["dur"],
                  "consumed": m["consumed"], "loss": m["loss"]}
            return (state, times, ctl, energy), ys

        def run_k(state, times, ctl, energy):
            return jax.lax.scan(body, (state, times, ctl, energy), None,
                                length=K)

        donate = (0,) if jax.default_backend() != "cpu" else ()
        jit_kw = dict(donate_argnums=donate)
        if self.placement.is_sharded:
            # carry: FleetState by leaf group, the per-cluster event-time
            # vector with the cluster stack, policy carry + energy tally
            # replicated; the K stacked metrics replicate (synced once)
            repl = self.placement.replicated()
            carry_sh = (self.placement.state_shardings(self.state),
                        self.placement.sharding(self.placement.cluster_axis),
                        self.placement.tree_replicated(pol.state), repl)
            ys_sh = {k: repl for k in ("t", "cluster", "a", "dur",
                                       "consumed", "loss")}
            jit_kw.update(in_shardings=carry_sh,
                          out_shardings=(carry_sh, ys_sh))
        return jax.jit(run_k, **jit_kw)

    def run_scanned(self, K: int, *, eval_final: bool = True) -> FLTrace:
        """Run exactly K asynchronous cluster rounds as one `lax.scan`.

        The whole control loop — cluster scheduling, the controller's
        `select` (via its `scan_policy()`), the fused round, the Eqn-12
        queue advance — compiles into a single device program; stacked
        per-round metrics cross the host boundary **once**, after round K.
        Per-round records carry the round's mean training loss (no
        intermediate global models exist on the host to evaluate);
        ``eval_final`` appends one evaluation record for the final model.

        Requires a mask-aware aggregator (the padded fixed-shape round) and
        a controller exposing ``scan_policy()``; use the event-heap `run`
        for exact-shape robust rules, ``sim_seconds`` cutoffs, or per-round
        evaluation.

        Consecutive calls *continue*: the per-cluster event-time vector
        carries across calls (as the round counter and energy tally always
        did), so ``run_scanned(K)`` twice produces the exact trace
        ``run_scanned(2K)`` would — the segment invariant `repro.serve`
        checkpoints and resumes on.
        """
        if not self._padded:
            raise ValueError(
                f"aggregator {type(self.aggregator).__name__} has "
                "supports_mask=False (exact-shape compiles); run_scanned "
                "needs the padded fused round — use run() instead")
        scan_policy = getattr(self.controller, "scan_policy", None)
        if scan_policy is None:
            raise ValueError(
                f"controller {type(self.controller).__name__} has no "
                "scan_policy(); use the event-heap run() instead")
        pol = scan_policy()
        K = int(K)
        args = (self.state, self._scan_times, pol.state,
                self._scan_energy_start())
        fn = self._scan_cache.get(K)
        if fn is None:
            fn = self._instrument_compile(
                f"run_scanned[K={K}]", self._build_scan_fn(K, pol), args)
            self._scan_cache[K] = fn
        if self.obs is None:
            out = fn(*args)
        else:
            # fenced round span: `mark` stamps the async-dispatch time,
            # the fence charges the span for the device compute it queued
            with self.obs.span("round", mode="scanned", rounds=K) as sp:
                out = fn(*args)
                sp.mark("dispatch")
                jax.block_until_ready(out)
        (state, times, _, energy_end), ys = out
        self.state = state
        self._scan_times = times        # schedule carries to the next call
        return self._emit_scanned_trace(ys, K, eval_final, energy_end)

    # ------------------------------------------------------------------ #
    # scanned-trace emission + the deferred host sync behind it
    # ------------------------------------------------------------------ #
    def _scan_energy_start(self) -> jnp.ndarray:
        """The f32 energy tally a scan segment starts from.  While segments
        are pending, the device-side carry continues (one f32 stream, no
        host round-trip); a flushed engine re-seeds from the exact f64
        tally so a fresh scan matches the event loop bit for bit."""
        return self._energy_dev if self._pending else jnp.float32(
            self._energy_used)

    def _flush_pending(self) -> None:
        """Fold deferred per-segment consumed stacks into the host f64
        tally — the same sequential additions the per-scan sync performs,
        just batched across segments."""
        if not self._pending:
            return
        pend, self._pending = self._pending, []
        for chunk in jax.device_get(pend):
            for ci in np.asarray(chunk, np.float32):
                self._energy_used += float(ci)

    def _emit_scanned_trace(self, ys, K: int, eval_final: bool,
                            energy_end) -> FLTrace:
        """Turn a scan segment's stacked device metrics into a trace.

        Fast path: with no trace sink attached, retention off, and no
        final evaluation (the `repro.serve` segment loop between
        checkpoints), nothing here is host-visible — the segment's
        consumed stack is queued instead of synced and the f32 energy
        carry stays device-side, so back-to-back segments run without a
        per-segment `device_get`.  Anything host-facing flushes first.
        """
        base = self._rounds
        self._rounds += K
        sync_queue = getattr(self.controller, "sync_queue", None)
        if (self.trace_sink is None and not self.trace_retain
                and not eval_final):
            self._pending.append(ys["consumed"])
            self._energy_dev = energy_end
            if sync_queue is not None:
                sync_queue(self.state.queue)
            if self.obs is not None:
                # deferred path: keep the round counter honest, but do
                # not force the per-segment sync the path exists to avoid
                self.obs.m_rounds.inc(K)
            return self._new_trace()

        self._flush_pending()
        with self._obs_span("host_sync", rounds=K):
            ys = jax.device_get(ys)         # the one end-of-run sync
        # rebuild the float64 tally by the same sequential additions the
        # event loop performs (bitwise-identical cumulative energies)
        cum = []
        for ci in np.asarray(ys["consumed"], np.float32):
            self._energy_used += float(ci)
            cum.append(self._energy_used)
        if sync_queue is not None:          # host controller adopts the
            sync_queue(self.state.queue)    # device-resident backlog
        if self.obs is not None:
            self.obs.on_segment(ys, K, engine=self)

        trace = self._new_trace()
        for i in range(K):
            trace.append(RoundRecord(
                t=float(ys["t"][i]), round=base + i + 1,
                cluster=int(ys["cluster"][i]), a=int(ys["a"][i]),
                loss=float(ys["loss"][i]), acc=None, energy=cum[i],
                agg_count=base + i + 1))
        if eval_final:
            with self._obs_span("eval"):
                ev = self.task.evaluate(self.state.global_params,
                                        self.data)
            if self.obs is not None:
                self.obs.on_eval(ev["loss"], ev.get("acc"))
            trace.append(RoundRecord(
                t=float(ys["t"][-1]) + float(ys["dur"][-1]),
                round=self._rounds, cluster=int(ys["cluster"][-1]),
                a=int(ys["a"][-1]), loss=ev["loss"], acc=ev.get("acc"),
                energy=self._energy_used, agg_count=self._rounds))
        return trace

    # ------------------------------------------------------------------ #
    def run(self, eval_every: float = 1.0,
            max_rounds: Optional[int] = None) -> FLTrace:
        if self.spec.execution == "scanned":
            K = max_rounds if max_rounds is not None else self.spec.rounds
            return self.run_scanned(K)
        spec = self.spec
        self._flush_pending()   # the event loop tallies energy per round
        trace = self._new_trace()
        events = [(0.0, c) for c in range(spec.clustering.n_clusters)]
        heapq.heapify(events)
        t = 0.0
        next_eval = 0.0
        done = 0
        while events and t < spec.sim_seconds:
            if max_rounds is not None and done >= max_rounds:
                break
            t, c = heapq.heappop(events)
            if t >= spec.sim_seconds:
                break
            ctx = self._ctx(c) if self._needs_ctx else self._null_ctx(c)
            a_raw = int(self.controller.select(ctx))
            self.state, metrics = self._round_fn(
                self.state, c, a_raw, self._members[c], self._masks[c])
            self._rounds += 1
            done += 1
            m = jax.device_get(metrics)
            self._energy_used += float(m["consumed"])
            self.controller.observe(None, float(m["consumed"]),
                                    float(m["loss"]))
            if self.obs is not None:
                self.obs.on_round(
                    cluster=c, a=int(m["a"]), dur=float(m["dur"]),
                    consumed=float(m["consumed"]), loss=float(m["loss"]),
                    engine=self)
            heapq.heappush(events, (t + float(m["dur"]), c))
            if t >= next_eval:
                with self._obs_span("eval"):
                    ev = self.task.evaluate(self.state.global_params,
                                            self.data)
                if self.obs is not None:
                    self.obs.on_eval(ev["loss"], ev.get("acc"))
                trace.append(RoundRecord(
                    t=t, round=self._rounds, cluster=c, a=int(m["a"]),
                    loss=ev["loss"], acc=ev.get("acc"),
                    energy=self._energy_used,
                    agg_count=self._rounds))
                next_eval = t + eval_every
        return trace

    # legacy attribute views (shims, examples, tests) ------------------- #
    @property
    def rep(self):
        return self.state.rep

    @property
    def twins(self):
        return self.state.twins

    @property
    def channel(self):
        return self.state.channel

    @property
    def global_params(self):
        return self.state.global_params

    @property
    def cluster_params(self):
        return [jax.tree.map(lambda l, i=i: l[i], self.state.cluster_params)
                for i in range(self.spec.clustering.n_clusters)]

    @property
    def energy_used(self) -> float:
        self._flush_pending()
        return self._energy_used

    @property
    def agg_count(self) -> int:
        return self._rounds

    @property
    def round(self) -> int:
        return self._rounds


class DatacenterEngine:
    """Sharded fl_step (mode A/B) under the unified spec + trace schema.

    A smoke-scale driver of the datacenter path: the controller picks a_i
    per round exactly as at device scale (one pseudo-cluster ctx), trust
    reputations feed Eqn 6 inside the jit-ed step, staleness is zero
    (synchronous pods) unless the spec says otherwise.
    """

    @classmethod
    def from_spec(cls, spec: FederationSpec, *, controller, aggregator=None,
                  task, data=None, parts=None,
                  fused: Optional[bool] = None) -> "DatacenterEngine":
        # Eqn-6 trust weighting lives inside the jit-ed fl_step, and the
        # task adapter generates its own token batches: the aggregator
        # instance and the device-scale data/fused overrides are unused
        del aggregator, data, parts, fused
        return cls(spec, controller=controller, task=task)

    def __init__(self, spec: FederationSpec, *, controller, task):
        from repro.core import fl_step
        from repro.optim import adam
        self.spec = spec
        self.controller = controller
        self.task = task
        self.n_clusters = spec.clustering.n_clusters
        self.clients = max(1, spec.fleet.n_devices // self.n_clusters)
        self.opt = adam(task.lr)
        init = fl_step.build_init_fn(
            task.cfg, self.opt, mode=task.mode,
            n_clusters=self.n_clusters, clients_per_cluster=self.clients)
        self.key = jax.random.PRNGKey(spec.seed)
        self.state = init(self.key)
        self.rep = jnp.ones((self.n_clusters, self.clients))
        self._steps = {}
        self._fl = fl_step

    def _step(self, a: int):
        if a not in self._steps:
            self._steps[a] = jax.jit(self._fl.build_train_step(
                self.task.cfg, self.opt, mode=self.task.mode, local_steps=a))
        return self._steps[a]

    def run(self, eval_every: float = 1.0,
            max_rounds: Optional[int] = None) -> FLTrace:
        del eval_every                      # every round is recorded
        from repro.core.envs import OBS_DIM
        spec = self.spec
        trace = FLTrace()
        loss = float("nan")
        rounds = spec.rounds if max_rounds is None else min(spec.rounds,
                                                            max_rounds)
        for i in range(rounds):
            self.key, kb = jax.random.split(self.key)
            obs_feats = jnp.asarray([0.0 if np.isnan(loss) else loss,
                                     i / max(spec.rounds, 1), 0.0])
            ctx = ControllerCtx(
                round=i, cluster=0,
                obs=lambda f=obs_feats: jnp.pad(f, (0, OBS_DIM - 3)),
                cluster_loss=0.0 if np.isnan(loss) else loss,
                cluster_freq=1.0, mean_freq=1.0, channel_good_frac=1.0,
                energy_used=0.0)
            a = max(1, min(self.controller.select(ctx),
                           self.controller.n_actions))
            batch = self.task.make_batch(kb, self.n_clusters, self.clients)
            stale = jnp.zeros((self.n_clusters,))
            self.state, metrics = self._step(a)(
                self.state, batch, self.rep, stale)
            loss = float(jnp.mean(metrics["loss"]))
            # no energy model at datacenter scale: report zero consumption
            # (a raw step count would corrupt a Lyapunov queue's units)
            self.controller.observe(ctx, 0.0, loss)
            trace.append(RoundRecord(
                t=float(i), round=i + 1, cluster=-1, a=a, loss=loss,
                acc=None, energy=0.0, agg_count=i + 1))
        return trace

    def run_scanned(self, K: int, *, eval_final: bool = True) -> FLTrace:
        raise ValueError(
            "the datacenter engine has no scanned lowering (its round loop "
            "is already a fixed-shape jit step per round); use run()")


def default_device_data(spec: FederationSpec):
    """Synthetic non-IID federated data from the task params (the
    device-scale default when `from_spec` gets no data/parts override).

    Deterministic in ``spec.seed`` — a fresh process rebuilding an engine
    from the same spec regenerates identical data and shards, which is what
    lets `repro.serve` checkpoint only the `FleetState` and not the
    dataset.  Dispatches on the task kind: classification tasks draw the
    MNIST-shaped prototype mixture; the reconstruction task draws IoT
    telemetry and partitions it by device type (each client sees mostly one
    equipment family — non-IID in the covariates rather than the labels).
    """
    from repro.data import (dirichlet_partition, make_classification,
                            make_iot_telemetry)
    p = spec.task.params
    key = jax.random.PRNGKey(spec.seed)
    if spec.task.kind == "autoencoder-anomaly":
        data = make_iot_telemetry(
            key, n=p.get("n_samples", 2048), dim=p.get("dim", 32),
            n_types=p.get("n_types", 8), latent=p.get("latent", 4),
            anomaly_frac=p.get("anomaly_frac", 0.05),
            noise=p.get("noise", 0.05))
        parts = dirichlet_partition(key, data.device_type,
                                    spec.fleet.n_devices,
                                    alpha=p.get("dirichlet_alpha", 0.5),
                                    n_classes=p.get("n_types", 8))
        return data, parts
    data = make_classification(key, n=p.get("n_samples", 4096),
                               dim=p.get("dim", 784))
    parts = dirichlet_partition(key, data.y, spec.fleet.n_devices,
                                alpha=p.get("dirichlet_alpha", 0.5))
    return data, parts


class DeviceScaleGspmdEngine(DeviceScaleEngine):
    """The jit-sharded GSPMD path, pinned: ``scale='device-gspmd'`` runs
    `DeviceScaleEngine` itself even where a 1-D mesh would resolve to the
    cluster-major shard_map engine.  (Equivalent per-spec escape hatch:
    ``ShardingSpec.impl='gspmd'``.)"""


# `scale` resolves through the same registry mechanism as every other
# component; a new execution scale is a registration, not a facade edit
register_engine(DEVICE_SCALE)(DeviceScaleEngine)
register_engine(GSPMD_DEVICE_SCALE)(DeviceScaleGspmdEngine)
register_engine(DATACENTER_SCALE)(DatacenterEngine)
