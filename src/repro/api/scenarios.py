"""Scenario presets: named `FederationSpec` builders.

Imported by the package __init__ so `SCENARIOS` is populated on
``import repro.api``; the CLI (`python -m repro.api.run`) resolves from the
same registry, and downstream code can add presets with
``@register_scenario("name")``.
"""
from __future__ import annotations

from .registry import register_scenario
from .spec import (AggregatorSpec, ChannelSpec, ClusteringSpec,
                   ControllerSpec, DATACENTER_SCALE, FaultSpec,
                   FederationSpec, FleetSpec, PrivacySpec, ShardingSpec,
                   TaskSpec)


@register_scenario("sync-baseline")
def _sync_baseline() -> FederationSpec:
    """Benchmark scheme: synchronous FedAvg, one cluster, fixed a=5."""
    return FederationSpec(
        clustering=ClusteringSpec(n_clusters=1),
        controller=ControllerSpec("fixed", {"a": 5}),
        aggregator=AggregatorSpec("fedavg"),
        sim_seconds=15.0)


@register_scenario("byzantine")
def _byzantine() -> FederationSpec:
    """25% label-flipping clients; trust aggregation must down-weight them."""
    return FederationSpec(
        fleet=FleetSpec(n_devices=16, malicious_frac=0.25),
        controller=ControllerSpec("fixed", {"a": 5}),
        aggregator=AggregatorSpec("trust"),
        sim_seconds=15.0)


@register_scenario("faulty-fleet")
def _faulty_fleet() -> FederationSpec:
    """Declarative fault injection inside the jitted round: device dropout,
    stragglers, twin-deviation spikes, and sign-flip Byzantine corruption,
    with trust aggregation absorbing the damage (`repro.faults`)."""
    return FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 5}),
        aggregator=AggregatorSpec("trust"),
        faults=FaultSpec(dropout=0.15, straggler_frac=0.125,
                         twin_spike_prob=0.1, corrupt_mode="sign_flip",
                         corrupt_frac=0.25, corrupt_scale=4.0),
        execution="scanned", rounds=30, sim_seconds=1e9)


@register_scenario("dp")
def _dp() -> FederationSpec:
    """Client-level DP on top of trust aggregation."""
    return FederationSpec(
        controller=ControllerSpec("fixed", {"a": 5}),
        privacy=PrivacySpec(clip=1.0, noise=0.5),
        sim_seconds=15.0)


@register_scenario("heterogeneous")
def _heterogeneous() -> FederationSpec:
    """Wide DT deviation + bad channel; Lyapunov-greedy frequency control."""
    return FederationSpec(
        fleet=FleetSpec(n_devices=16, dt_max_dev=0.4),
        channel=ChannelSpec(p_good=0.3),
        controller=ControllerSpec("lyapunov",
                                  {"budget": 150.0, "horizon": 60}),
        sim_seconds=15.0)


@register_scenario("adaptive")
def _adaptive() -> FederationSpec:
    """The paper's full scheme: DQN trained on the DT env picks a_i."""
    return FederationSpec(
        controller=ControllerSpec("dqn", {"episodes": 3, "horizon": 20}),
        sim_seconds=15.0)


@register_scenario("adaptive-scanned")
def _adaptive_scanned() -> FederationSpec:
    """Full scheme, sync-free: scanned DQN pretrain + lax.scan-over-rounds."""
    return FederationSpec(
        controller=ControllerSpec("dqn", {"episodes": 3, "horizon": 20}),
        execution="scanned", rounds=40, sim_seconds=15.0)


@register_scenario("adaptive-scanned-sharded")
def _adaptive_scanned_sharded() -> FederationSpec:
    """Scanned full scheme on an 8-way fleet mesh (API.md "Placement")."""
    return FederationSpec(
        fleet=FleetSpec(n_devices=16),
        controller=ControllerSpec("dqn", {"episodes": 3, "horizon": 20}),
        execution="scanned", rounds=40, sim_seconds=15.0,
        sharding=ShardingSpec(mesh=(8,)))


@register_scenario("autoencoder-anomaly")
def _autoencoder_anomaly() -> FederationSpec:
    """Federated autoencoder anomaly detection on non-IID IoT telemetry
    (reconstruction loss; trace ``acc`` is the detection AUC).  Scanned
    execution under Lyapunov frequency control — the long-running workload
    `python -m repro.serve` runs in checkpointed segments."""
    return FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=ClusteringSpec(n_clusters=4),
        controller=ControllerSpec("lyapunov",
                                  {"budget": 1600.0, "horizon": 100}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 2048, "dim": 32, "n_types": 8,
                       "hidden": 64, "code": 8}),
        execution="scanned", rounds=25, sim_seconds=1e9,
        local_batch=32, lr=0.1)


@register_scenario("lm-modeA")
def _lm_mode_a() -> FederationSpec:
    """Datacenter scale: tiny-LM FedAvg-replica (fl_step mode A)."""
    return FederationSpec(
        scale=DATACENTER_SCALE,
        fleet=FleetSpec(n_devices=8),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 2, "n_actions": 4}),
        task=TaskSpec("lm", {"seq": 16, "micro_batch": 2}),
        rounds=5)
