"""Component registries for the unified federation API.

Every pluggable piece of the pipeline — aggregation rule, frequency
controller, task adapter, scenario preset — registers itself under a string
name, so a `FederationSpec` (and therefore a config file) can name any
component without the orchestrator knowing about it:

    @register_aggregator("krum")
    def _build(params):
        ...return an Aggregator...

Lookups raise ``KeyError`` with the available names, so a typo in a config
fails loudly at build time rather than silently falling back.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List


class Registry:
    """A named string -> factory mapping with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._factories: Dict[str, Callable[..., Any]] = {}

    def register(self, name: str) -> Callable:
        def deco(factory):
            if name in self._factories:
                raise ValueError(
                    f"duplicate {self.kind} registration: {name!r}")
            self._factories[name] = factory
            return factory
        return deco

    def get(self, name: str) -> Callable[..., Any]:
        try:
            return self._factories[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered: "
                f"{sorted(self._factories)}") from None

    def names(self) -> List[str]:
        return sorted(self._factories)

    def __contains__(self, name: str) -> bool:
        return name in self._factories


AGGREGATORS = Registry("aggregator")
CONTROLLERS = Registry("controller")
TASKS = Registry("task")
SCENARIOS = Registry("scenario")
# execution engines, keyed by `FederationSpec.scale` — entries must satisfy
# the `repro.api.engine.Engine` protocol (classmethod ``from_spec`` plus
# ``run``/``run_scanned`` emitting the FLTrace schema)
ENGINES = Registry("engine")

register_aggregator = AGGREGATORS.register
register_controller = CONTROLLERS.register
register_task = TASKS.register
register_scenario = SCENARIOS.register
register_engine = ENGINES.register
