"""Shared neural-net building blocks (pure JAX, functional style).

Every module is a pair of functions: ``init_*(key, ...) -> params`` and the
forward application.  Params are plain dict pytrees so they stack cleanly for
scan-over-layers and vmap-over-clients (FL mode A).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(fan_in))
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# --------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------- #
def init_rmsnorm(d):
    return jnp.ones((d,), jnp.float32)


def rmsnorm(w, x, eps=1e-6):
    """Mean-square reduction in f32; the (B,S,D)-sized elementwise products
    stay in the activation dtype — casting the whole tensor to f32 doubled
    the dominant fwd+bwd HBM streams (§Perf pair 3, iter 1)."""
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * scale * w.astype(x.dtype)


# --------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------- #
def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------- #
def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, (d_model, d_ff), dtype=dtype),
        "wu": dense_init(ku, (d_model, d_ff), dtype=dtype),
        "wd": dense_init(kd, (d_ff, d_model), dtype=dtype),
    }


def mlp(params, x, activation="silu"):
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    h = act(x @ params["wg"]) * (x @ params["wu"])
    return h @ params["wd"]


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x
