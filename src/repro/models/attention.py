"""Attention blocks: GQA/MQA/MHA (optional bias, qk-norm, logit softcap,
sliding window) and DeepSeek-style MLA (multi-head latent attention).

Two paths per block:
  * ``*_forward``  — full-sequence causal attention (training / prefill),
  * ``*_decode``   — one-token step against a (ring-buffer) KV cache.

The KV cache for LOCAL (sliding-window) layers is a ring buffer of width
``window``; stored absolute positions (init -1) drive validity masks, and RoPE
is applied at write time with absolute positions, so relative offsets stay
correct across wraparound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig, LOCAL
from .modules import apply_rope, dense_init, init_rmsnorm, rmsnorm, softcap

NEG_INF = -2.0e38


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def init_attn(key, cfg: ArchConfig, dtype=jnp.float32):
    if cfg.use_mla:
        return _init_mla(key, cfg, dtype)
    ks = jax.random.split(key, 6)
    hd = cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.num_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.num_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.num_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["qnorm"] = init_rmsnorm(hd)
        p["knorm"] = init_rmsnorm(hd)
    return p


def _init_mla(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype=dtype)
        p["q_norm"] = init_rmsnorm(cfg.q_lora_rank)
        p["wq_b"] = dense_init(ks[1], (cfg.q_lora_rank, cfg.num_heads * qk), dtype=dtype)
    else:
        p["wq"] = dense_init(ks[0], (cfg.d_model, cfg.num_heads * qk), dtype=dtype)
    p["wkv_a"] = dense_init(
        ks[2], (cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype=dtype)
    p["kv_norm"] = init_rmsnorm(cfg.kv_lora_rank)
    p["wkv_b"] = dense_init(
        ks[3], (cfg.kv_lora_rank,
                cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)), dtype=dtype)
    p["wo"] = dense_init(
        ks[4], (cfg.num_heads * cfg.v_head_dim, cfg.d_model), dtype=dtype)
    return p


# --------------------------------------------------------------------- #
# core sdpa with grouped heads
# --------------------------------------------------------------------- #
def _sdpa(q, k, v, mask, scale, cap):
    """q: (B,S,H,dq) k: (B,T,Kv,dq) v: (B,T,Kv,dv); mask: (B,1,1,S,T)|None."""
    B, S, H, dq = q.shape
    Kv = k.shape[2]
    g = H // Kv
    q = q.reshape(B, S, Kv, g, dq)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    scores = softcap(scores, cap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, v.shape[-1])


def causal_mask(S, T, offset=0, window=0):
    """(S,T) bool; query i attends key j iff j <= i+offset (and within
    window for sliding attention)."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def _sdpa_chunked(q, k, v, scale, cap, window, chunk):
    """Query-chunked causal attention (lax.map over Q blocks) — keeps the
    scores working set at (B,Kv,g,chunk,T) instead of (B,Kv,g,S,S); this is
    what makes prefill_32k lower within HBM (DESIGN.md §6, and the jnp
    analogue of kernels/flash_attention.py)."""
    B, S, H, dq = q.shape
    T = k.shape[1]
    nq = S // chunk
    assert nq * chunk == S, (S, chunk)
    qc = q.reshape(B, nq, chunk, H, dq).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(args):
        # inner remat: without it, lax.map's VJP streams the f32 score and
        # softmax tensors of every chunk to HBM as residuals — recomputing
        # them from (q,k,v) is cheaper than the traffic (§Perf pair 3)
        i, qb = args
        mask = causal_mask(chunk, T, offset=i * chunk, window=window)
        return _sdpa(qb, k, v, mask[None, None, None], scale, cap)

    outs = jax.lax.map(body, (jnp.arange(nq), qc))      # (nq,B,chunk,H,dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, -1)


# --------------------------------------------------------------------- #
# GQA forward / decode
# --------------------------------------------------------------------- #
def _project_qkv(p, cfg, x):
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[:2]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q, k = rmsnorm(p["qnorm"], q), rmsnorm(p["knorm"], k)
    return q, k, v


def _ring_cache(k, v, pos, Wc, dtype):
    """Pack the last Wc (roped) keys/values into ring-buffer slot order so
    decode can continue: slot = position % Wc."""
    B, S = k.shape[:2]
    take = min(S, Wc)
    tail_pos = jnp.arange(S - take, S)
    slots = tail_pos % Wc
    ck = jnp.zeros((B, Wc) + k.shape[2:], dtype).at[:, slots].set(
        k[:, S - take:].astype(dtype))
    cv = jnp.zeros((B, Wc) + v.shape[2:], dtype).at[:, slots].set(
        v[:, S - take:].astype(dtype))
    cpos = jnp.full((Wc,), -1, jnp.int32).at[slots].set(tail_pos)
    return {"k": ck, "v": cv, "pos": cpos}


def attn_forward(p, cfg: ArchConfig, x, kind: str, q_chunk: int = 0,
                 return_cache: bool = False, cache_len: int = 0,
                 cache_dtype=jnp.bfloat16):
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    pos = jnp.arange(S)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    window = cfg.window if kind == LOCAL else 0
    scale = cfg.head_dim ** -0.5
    if q_chunk and S > q_chunk:
        out = _sdpa_chunked(q, k, v, scale, cfg.attn_softcap, window, q_chunk)
    else:
        mask = causal_mask(S, S, window=window)[None, None, None]
        out = _sdpa(q, k, v, mask, scale, cfg.attn_softcap)
    y = out.reshape(B, S, -1) @ p["wo"]
    if not return_cache:
        return y
    Wc = min(cache_len, cfg.window) if (kind == LOCAL and cfg.window) else cache_len
    return y, _ring_cache(k, v, pos, Wc, cache_dtype)


def init_attn_cache(cfg: ArchConfig, batch, max_len, kind, dtype=jnp.bfloat16):
    Wc = min(max_len, cfg.window) if kind == LOCAL else max_len
    if cfg.use_mla:
        return {
            "ckv": jnp.zeros((batch, Wc, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, Wc, cfg.qk_rope_dim), dtype),
            "pos": jnp.full((Wc,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, Wc, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, Wc, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((Wc,), -1, jnp.int32),
    }


def _decode_replicate_heads(cfg: ArchConfig, *tensors):
    """When the head counts don't divide the model axis (gemma-2b H=8,
    qwen kv=40, grok/granite/chameleon kv=8), GSPMD pads the head shard and
    falls back to all-gathering the f32-converted KV cache across `model`
    (measured 12 GB/token on gemma-2b decode).  Pinning the small decode
    q/k/v to batch-only sharding makes XLA gather the ~16 KB query instead
    of the multi-GB cache."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if (mesh is None or not mesh.axis_names
                or "model" not in mesh.axis_names
                or "data" not in mesh.axis_names):
            return tensors
        tp = mesh.shape["model"]
        if cfg.num_heads % tp == 0 and cfg.num_kv_heads % tp == 0:
            return tensors
        from jax.sharding import PartitionSpec
        out = tuple(
            jax.lax.with_sharding_constraint(
                t, PartitionSpec(*(["data"] + [None] * (t.ndim - 1))))
            for t in tensors)
        return out
    except Exception:
        return tensors


def attn_decode(p, cfg: ArchConfig, x, cache, step, kind: str):
    """x: (B,1,D); step: scalar int32 absolute position. Returns (y, cache)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    q, k, v = _decode_replicate_heads(cfg, q, k, v)
    q = apply_rope(q, step[None], cfg.rope_theta)
    k = apply_rope(k, step[None], cfg.rope_theta)
    Wc = cache["k"].shape[1]
    slot = step % Wc
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    cpos = cache["pos"].at[slot].set(step)
    window = cfg.window if kind == LOCAL else 0
    valid = (cpos >= 0) & (cpos <= step)
    if window > 0:
        valid &= cpos > step - window
    mask = valid[None, None, None, None, :]
    out = _sdpa(q, ck, cv, mask, cfg.head_dim ** -0.5, cfg.attn_softcap)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": ck, "v": cv, "pos": cpos}


# --------------------------------------------------------------------- #
# MLA forward / decode
# --------------------------------------------------------------------- #
def _mla_q(p, cfg, x):
    B, S = x.shape[:2]
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = rmsnorm(p["q_norm"], x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, cfg.num_heads, qk)
    return jnp.split(q, [cfg.qk_nope_dim], axis=-1)  # q_nope, q_rope


def mla_forward(p, cfg: ArchConfig, x, kind: str, q_chunk: int = 0,
                return_cache: bool = False, cache_len: int = 0,
                cache_dtype=jnp.bfloat16):
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q_nope, q_rope = _mla_q(p, cfg, x)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    ckv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # (B,S,1,r)
    kv = (c_kv @ p["wkv_b"]).reshape(
        B, S, cfg.num_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, cfg.num_heads, cfg.qk_rope_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    if q_chunk and S > q_chunk:
        out = _sdpa_chunked(q, k, v, scale, cfg.attn_softcap, 0, q_chunk)
    else:
        mask = causal_mask(S, S)[None, None, None]
        out = _sdpa(q, k, v, mask, scale, cfg.attn_softcap)
    y = out.reshape(B, S, -1) @ p["wo"]
    if not return_cache:
        return y
    Wc = cache_len
    take = min(S, Wc)
    tail = jnp.arange(S - take, S)
    slots = tail % Wc
    cc = jnp.zeros((B, Wc, cfg.kv_lora_rank), cache_dtype).at[:, slots].set(
        c_kv[:, S - take:].astype(cache_dtype))
    cr = jnp.zeros((B, Wc, cfg.qk_rope_dim), cache_dtype).at[:, slots].set(
        k_rope[:, S - take:, 0].astype(cache_dtype))
    cpos = jnp.full((Wc,), -1, jnp.int32).at[slots].set(tail)
    return y, {"ckv": cc, "krope": cr, "pos": cpos}


def mla_decode_absorbed(p, cfg: ArchConfig, x, cache, step, kind: str):
    """Absorbed-matrix MLA decode (beyond-paper §Perf optimization).

    Instead of re-expanding K/V for every cached position
    (S·r·H·(nope+v) FLOPs/token — the naive path's MF/HLO was 0.001),
    fold W_UK into the query and W_UV into the output:
        scores_h = (q_nope_h W_UK_h^T) · c  +  q_rope_h · k_rope
        out_h    = (sum_t w_t c_t) W_UV_h
    Attention then runs directly in the latent space: H·S·r FLOPs/token.
    """
    B = x.shape[0]
    H, r = cfg.num_heads, cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, cfg, x)                   # (B,1,H,*)
    q_rope = apply_rope(q_rope, step[None], cfg.rope_theta)
    ckv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], step[None], cfg.rope_theta)[:, :, 0]
    Wc = cache["ckv"].shape[1]
    slot = step % Wc
    cc = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], c_kv.astype(cache["ckv"].dtype), slot, 1)
    cr = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], k_rope.astype(cache["krope"].dtype), slot, 1)
    cpos = cache["pos"].at[slot].set(step)

    # absorb: W_UK (r, H, nope), W_UV (r, H, v)
    wkv_b = p["wkv_b"].reshape(r, H, cfg.qk_nope_dim + cfg.v_head_dim)
    w_uk, w_uv = jnp.split(wkv_b, [cfg.qk_nope_dim], axis=-1)
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)   # (B,H,r)

    c = cc.astype(x.dtype)                                   # (B,Wc,r)
    s_lat = jnp.einsum("bhr,btr->bht", q_eff, c)
    s_rope = jnp.einsum("bhd,btd->bht", q_rope[:, 0], cr.astype(x.dtype))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    s = (s_lat + s_rope).astype(jnp.float32) * scale
    s = softcap(s, cfg.attn_softcap)
    valid = (cpos >= 0) & (cpos <= step)
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)           # (B,H,Wc)
    ctx = jnp.einsum("bht,btr->bhr", w, c)                   # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv)              # (B,H,v)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"ckv": cc, "krope": cr, "pos": cpos}


def mla_decode(p, cfg: ArchConfig, x, cache, step, kind: str):
    """Latent-cache decode (naive expansion of K/V from c_kv per step —
    kept as the reference; serving uses mla_decode_absorbed when
    cfg.mla_absorbed, see EXPERIMENTS.md §Perf)."""
    if cfg.mla_absorbed:
        return mla_decode_absorbed(p, cfg, x, cache, step, kind)
    B = x.shape[0]
    q_nope, q_rope = _mla_q(p, cfg, x)
    q_rope = apply_rope(q_rope, step[None], cfg.rope_theta)
    ckv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], step[None], cfg.rope_theta)[:, :, 0]
    Wc = cache["ckv"].shape[1]
    slot = step % Wc
    cc = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], c_kv.astype(cache["ckv"].dtype), slot, 1)
    cr = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], k_rope.astype(cache["krope"].dtype), slot, 1)
    cpos = cache["pos"].at[slot].set(step)
    # expand K/V for all cached latents
    kv = (cc.astype(x.dtype) @ p["wkv_b"]).reshape(
        B, Wc, cfg.num_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(cr.astype(x.dtype)[:, :, None, :],
                          (B, Wc, cfg.num_heads, cfg.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    valid = (cpos >= 0) & (cpos <= step)
    mask = valid[None, None, None, None, :]
    out = _sdpa(q, k, v, mask, scale, cfg.attn_softcap)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"ckv": cc, "krope": cr, "pos": cpos}
