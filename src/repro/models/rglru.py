"""Griffin RG-LRU recurrent block (recurrentgemma-2b).

Block: x -> [W_x -> causal conv -> RG-LRU] * gelu(W_gate x) -> W_out.
RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(x W_a + b_a)            recurrence gate
    i_t = sigmoid(x W_i + b_i)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Forward is a lax.scan; kernels/rglru_scan.py is the TPU hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .modules import dense_init

_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    D, W, K = cfg.d_model, cfg.lru_width, cfg.ssm_conv
    return {
        "w_x": dense_init(ks[0], (D, W), dtype=dtype),
        "w_gate": dense_init(ks[1], (D, W), dtype=dtype),
        "conv_w": dense_init(ks[2], (K, W), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_a": dense_init(ks[3], (W, W), dtype=dtype),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": dense_init(ks[4], (W, W), dtype=dtype),
        "b_i": jnp.zeros((W,), jnp.float32),
        "lam": jnp.linspace(0.9, 5.0, W),          # softplus(lam) spans decay rates
        "w_out": dense_init(ks[5], (W, D), dtype=dtype),
    }


def _gates(p, xc):
    r = jax.nn.sigmoid((xc @ p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid((xc @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i


from .mamba import _causal_conv  # shared depthwise causal conv


def rglru_forward(p, cfg: ArchConfig, x, return_state: bool = False):
    """x: (B,S,D) -> (B,S,D) [, decode cache]."""
    B, S, _ = x.shape
    gate = jax.nn.gelu(x @ p["w_gate"])
    xin = x @ p["w_x"]
    xr = _causal_conv(xin, p["conv_w"], p["conv_b"])
    a, bi = _gates(p, xr)                                  # (B,S,W) fp32

    def step(h, inp):
        a_t, bix_t = inp
        h = a_t * h + bix_t
        return h, h

    xs = (a.swapaxes(0, 1), (bi * xr.astype(jnp.float32)).swapaxes(0, 1))
    h0 = jnp.zeros((B, cfg.lru_width), jnp.float32)
    from .mamba import SEQ_UNROLL
    h_last, hs = jax.lax.scan(step, h0, xs, unroll=min(SEQ_UNROLL, S))
    y = hs.swapaxes(0, 1).astype(x.dtype) * gate
    out = y @ p["w_out"]
    if not return_state:
        return out
    K = cfg.ssm_conv
    conv_tail = xin[:, max(0, S - (K - 1)):, :]
    if S < K - 1:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"h": h_last, "conv": conv_tail}


def init_rglru_cache(cfg: ArchConfig, batch, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.lru_width), dtype),
    }


def rglru_decode(p, cfg: ArchConfig, x, cache, step):
    """x: (B,1,D) one-token step."""
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"])
    xin = x[:, 0] @ p["w_x"]
    hist = jnp.concatenate(
        [cache["conv"], xin[:, None].astype(cache["conv"].dtype)], axis=1)
    xr = jnp.einsum("bkw,kw->bw", hist.astype(x.dtype), p["conv_w"]) + p["conv_b"]
    a, bi = _gates(p, xr)
    h = a * cache["h"] + bi * xr.astype(jnp.float32)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y[:, None], {"h": h, "conv": hist[:, 1:]}
