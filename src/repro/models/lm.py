"""Language-model losses over the transformer substrate."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .transformer import forward

MOE_AUX_WEIGHT = 0.01


def xent(logits, labels):
    """Mean token cross-entropy. logits (..., V), labels (...)."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_loss(params, cfg: ArchConfig, batch, remat: bool = True,
            q_chunk: int = 0):
    """batch = {"tokens": (B,S)|(B,K,S), "labels": same} -> scalar loss."""
    logits, aux = forward(params, cfg, batch["tokens"], remat=remat,
                          q_chunk=q_chunk)
    loss = xent(logits, batch["labels"])
    if cfg.num_experts:
        loss = loss + MOE_AUX_WEIGHT * aux
    return loss


def weighted_lm_loss(params, cfg: ArchConfig, batch, example_weights,
                     remat: bool = True, q_chunk: int = 0):
    """Trust-weighted loss (FL mode B): per-example weights make the implicit
    gradient all-reduce the trust-weighted aggregation (DESIGN.md §2).

    example_weights: (B,) normalized trust weights of each example's client.
    """
    logits, aux = forward(params, cfg, batch["tokens"], remat=remat,
                          q_chunk=q_chunk)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), batch["labels"][..., None], axis=-1)[..., 0]
    per_tok = logz - gold                       # (B,S) or (B,K,S)
    w = example_weights
    while w.ndim < per_tok.ndim:
        w = w[..., None]
    loss = jnp.sum(per_tok * w) / (jnp.sum(jnp.broadcast_to(w, per_tok.shape)) + 1e-9)
    if cfg.num_experts:
        loss = loss + MOE_AUX_WEIGHT * aux
    return loss
