"""Mamba-1 selective state-space block (falcon-mamba-7b).

Forward uses a jnp `lax.scan` over the sequence (the Pallas `selective_scan`
kernel in kernels/ is the TPU hot-path realization, validated against
kernels/ref.py); decode is a single recurrence step with an O(1) state:
(B, d_inner, N) SSM state + (B, conv_k-1, d_inner) conv ring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .modules import dense_init

# §Perf pair-1 iteration: unrolling the selective-scan body lets XLA fuse
# consecutive recurrence steps, keeping h and the dA/dBx temporaries out of
# HBM between steps (measured: 4630s -> see EXPERIMENTS.md).  The Pallas
# selective_scan kernel is the full fix on TPU (state resident in VMEM).
SEQ_UNROLL = 64


def init_mamba(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    D, Di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, N))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * Di), dtype=dtype),
        "conv_w": dense_init(ks[1], (K, Di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((Di,), dtype),
        "x_proj": dense_init(ks[2], (Di, R + 2 * N), dtype=dtype),
        "dt_proj": dense_init(ks[3], (R, Di), dtype=dtype),
        "dt_bias": jnp.zeros((Di,), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((Di,), jnp.float32),
        "out_proj": dense_init(ks[4], (Di, D), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,Di), w: (K,Di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _ssm_params(p, cfg, xc):
    """xc: (..., Di) conv output -> (dt, B, C) selective params.
    dt streams through the seq scan: keep it in the activation dtype
    (fp32 dt doubled the dominant HBM stream — §Perf pair 1, iter 5)."""
    dbc = xc @ p["x_proj"]
    dt_r, Bc, Cc = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"] + p["dt_bias"]).astype(xc.dtype)
    return dt, Bc, Cc


def mamba_forward(p, cfg: ArchConfig, x, return_state: bool = False):
    """x: (B,S,D) -> (B,S,D) [, decode cache]."""
    B, S, D = x.shape
    Di, N = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    dt, Bc, Cc = _ssm_params(p, cfg, xc)                  # (B,S,Di) (B,S,N) (B,S,N)
    A = -jnp.exp(p["A_log"])                              # (Di,N)

    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp                        # (B,Di) (B,Di) (B,N) (B,N)
        dA = jnp.exp(dt_t[..., None] * A)                 # (B,Di,N)
        dBx = (dt_t * xc_t)[..., None] * B_t[:, None, :]  # (B,Di,N)
        h = dA * h.astype(jnp.float32) + dBx.astype(jnp.float32)
        # elementwise-mul + reduce instead of einsum: a dot is a fusion
        # barrier that forces h to HBM every step (§Perf pair 1, iter 2);
        # N=16 is far below MXU utility anyway
        y = (h * C_t[:, None, :].astype(jnp.float32)).sum(-1)
        return h, y.astype(x.dtype)

    h0 = jnp.zeros((B, Di, N), jnp.float32)
    xs = (xc.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bc.swapaxes(0, 1), Cc.swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0, xs,
                              unroll=min(SEQ_UNROLL, S))
    y = ys.swapaxes(0, 1) + xc * p["D"].astype(x.dtype)
    y = (y * jax.nn.silu(z)).astype(x.dtype)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    K = cfg.ssm_conv
    conv_tail = xin[:, max(0, S - (K - 1)):, :]
    if S < K - 1:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"h": h_last, "conv": conv_tail}


def init_mamba_cache(cfg: ArchConfig, batch, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(p, cfg: ArchConfig, x, cache, step):
    """x: (B,1,D) one-token step."""
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                    # (B,Di)
    hist = jnp.concatenate([cache["conv"], xin[:, None].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"]                                       # (K,Di)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist.astype(x.dtype), w) + p["conv_b"])
    dt, Bc, Cc = _ssm_params(p, cfg, xc)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    dBx = (dt * xc)[..., None] * Bc[:, None, :]
    h = dA * cache["h"] + dBx.astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)).astype(x.dtype)
    y = ((y + xc * p["D"].astype(x.dtype)) * jax.nn.silu(z)).astype(x.dtype)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}
