"""Decoder-only transformer substrate.

Supports every assigned architecture family through ``ArchConfig``:
dense / GQA / MQA / MLA attention, sliding-window attention, MoE MLPs,
Mamba-1 SSM blocks, RG-LRU recurrent blocks, multi-codebook audio heads.

Layer organization (keeps HLO small and compile fast on 64-layer configs):
    prefix   — ``first_dense_layers`` unrolled layers (deepseek dense layer 0)
    groups   — ``lax.scan`` over G repeats of ``block_pattern`` (remat'ed)
    suffix   — remainder layers (depth % pattern) unrolled

Params / caches are dict pytrees; ``param_specs`` mirrors the structure with
PartitionSpecs by leaf name (see DESIGN.md §5 for the sharding plan).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ATTN, LOCAL, MAMBA, RGLRU, ArchConfig
from .attention import (attn_decode, attn_forward, init_attn, init_attn_cache,
                        mla_decode, mla_forward)
from .mamba import init_mamba, init_mamba_cache, mamba_decode, mamba_forward
from .modules import init_mlp, init_rmsnorm, mlp, rmsnorm
from .moe import init_moe, moe_forward
from .rglru import init_rglru, init_rglru_cache, rglru_decode, rglru_forward


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _init_layer(key, cfg: ArchConfig, kind: str, layer_idx: int, dtype):
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": init_rmsnorm(cfg.d_model)}
    if kind in (ATTN, LOCAL):
        p["attn"] = init_attn(ks[0], cfg, dtype)
        p["ln2"] = init_rmsnorm(cfg.d_model)
        if cfg.num_experts and layer_idx >= cfg.first_dense_layers:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif kind == MAMBA:
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    elif kind == RGLRU:
        p["rglru"] = init_rglru(ks[0], cfg, dtype)
        p["ln2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    else:
        raise ValueError(kind)
    return p


def _split_depth(cfg: ArchConfig):
    """-> (prefix_idx, group_count, suffix_idx) over layer indices."""
    pat = len(cfg.block_pattern)
    pre = cfg.first_dense_layers
    rest = cfg.num_layers - pre
    groups = rest // pat
    suf_start = pre + groups * pat
    return list(range(pre)), groups, list(range(suf_start, cfg.num_layers))


def init_params(key, cfg: ArchConfig, dtype=jnp.float32):
    pre_idx, groups, suf_idx = _split_depth(cfg)
    kinds = cfg.layer_kinds()
    k_emb, k_body, k_head = jax.random.split(key, 3)

    params: Dict[str, Any] = {}
    eshape = (cfg.padded_vocab, cfg.d_model)
    if cfg.num_codebooks > 1:
        eshape = (cfg.num_codebooks,) + eshape
    params["embed"] = 0.02 * jax.random.normal(k_emb, eshape, dtype)

    layer_keys = jax.random.split(k_body, cfg.num_layers)
    params["prefix"] = [
        _init_layer(layer_keys[i], cfg, kinds[i], i, dtype) for i in pre_idx]

    pat = cfg.block_pattern
    if groups:
        stacked = []
        for j in range(len(pat)):
            per = [_init_layer(layer_keys[len(pre_idx) + g * len(pat) + j],
                               cfg, pat[j], len(pre_idx) + g * len(pat) + j,
                               dtype)
                   for g in range(groups)]
            stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
        params["groups"] = stacked
    else:
        params["groups"] = []

    params["suffix"] = [
        _init_layer(layer_keys[i], cfg, kinds[i], i, dtype) for i in suf_idx]

    params["final_norm"] = init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        hshape = (cfg.d_model, cfg.padded_vocab)
        if cfg.num_codebooks > 1:
            hshape = (cfg.num_codebooks,) + hshape
        params["lm_head"] = 0.02 * jax.random.normal(k_head, hshape, dtype)
    return params


# --------------------------------------------------------------------- #
# forward (training / prefill)
# --------------------------------------------------------------------- #
def _apply_layer(lp, cfg: ArchConfig, kind: str, x, is_moe: bool,
                 q_chunk: int = 0, cache_len: int = 0):
    """One residual layer.  cache_len > 0 => prefill mode: also return the
    decode cache (ring-buffer KV / recurrent state)."""
    aux = jnp.zeros((), jnp.float32)
    lcache = None
    h = rmsnorm(lp["ln1"], x)
    if kind in (ATTN, LOCAL):
        fwd = mla_forward if cfg.use_mla else attn_forward
        if cache_len:
            y, lcache = fwd(lp["attn"], cfg, h, kind, q_chunk=q_chunk,
                            return_cache=True, cache_len=cache_len)
        else:
            y = fwd(lp["attn"], cfg, h, kind, q_chunk=q_chunk)
        x = x + y
        h2 = rmsnorm(lp["ln2"], x)
        if is_moe:
            y, aux = moe_forward(lp["moe"], cfg, h2)
        else:
            y = mlp(lp["mlp"], h2, cfg.activation)
        x = x + y
    elif kind == MAMBA:
        if cache_len:
            y, lcache = mamba_forward(lp["mamba"], cfg, h, return_state=True)
        else:
            y = mamba_forward(lp["mamba"], cfg, h)
        x = x + y
    elif kind == RGLRU:
        if cache_len:
            y, lcache = rglru_forward(lp["rglru"], cfg, h, return_state=True)
        else:
            y = rglru_forward(lp["rglru"], cfg, h)
        x = x + y
        x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x), cfg.activation)
    if cache_len:
        return x, aux, lcache
    return x, aux


def embed_tokens(params, cfg: ArchConfig, tokens):
    """tokens: (B,S) int32 or (B,K,S) for multi-codebook audio."""
    if cfg.num_codebooks > 1:
        # sum codebook embeddings: embed (K,V,D), tokens (B,K,S)
        embs = jnp.take_along_axis(
            params["embed"][None],                     # (1,K,V,D)
            tokens.transpose(0, 1, 2)[..., None],      # (B,K,S,1)
            axis=2)
        x = embs.sum(axis=1)                           # (B,S,D)
    else:
        x = params["embed"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(params, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        head = params["embed"]
        if cfg.num_codebooks > 1:
            logits = jnp.einsum("bsd,kvd->bksv", x, head)
        else:
            logits = x @ head.T
    else:
        head = params["lm_head"]
        if cfg.num_codebooks > 1:
            logits = jnp.einsum("bsd,kdv->bksv", x, head)
        else:
            logits = x @ head
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad-vocab logits (elementwise; no resharding of the vocab dim)
        vocab_ids = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vocab_ids < cfg.vocab_size, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return logits


def forward(params, cfg: ArchConfig, tokens, remat: bool = True,
            q_chunk: int = 0):
    """-> (logits, moe_aux).  logits (B,S,V) or (B,K,S,V) for audio."""
    kinds = cfg.layer_kinds()
    pre_idx, groups, suf_idx = _split_depth(cfg)
    x = embed_tokens(params, cfg, tokens)
    aux = jnp.zeros((), jnp.float32)

    for i, lp in zip(pre_idx, params["prefix"]):
        x, a = _apply_layer(lp, cfg, kinds[i], x,
                            is_moe=bool(cfg.num_experts) and i >= cfg.first_dense_layers,
                            q_chunk=q_chunk)
        aux = aux + a

    if groups:
        pat = cfg.block_pattern
        moe_flags = [bool(cfg.num_experts) and (len(pre_idx) + j) >= cfg.first_dense_layers
                     for j in range(len(pat))]

        def group_body(carry, gp):
            x, aux = carry
            for j, kind in enumerate(pat):
                x, a = _apply_layer(gp[j], cfg, kind, x, moe_flags[j],
                                    q_chunk=q_chunk)
                aux = aux + a
            return (x, aux), None

        body = jax.checkpoint(group_body) if remat else group_body
        if cfg.unroll_layers:
            for g in range(groups):
                gp = jax.tree.map(lambda a: a[g], tuple(params["groups"]))
                (x, aux), _ = body((x, aux), gp)
        elif cfg.scan_indexed:
            stacked = tuple(params["groups"])

            def idx_body(carry, g):
                gp = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, g, 0, keepdims=False), stacked)
                return body(carry, gp)

            (x, aux), _ = jax.lax.scan(idx_body, (x, aux),
                                       jnp.arange(groups))
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux),
                                       tuple(params["groups"]))

    for i, lp in zip(suf_idx, params["suffix"]):
        x, a = _apply_layer(lp, cfg, kinds[i], x,
                            is_moe=bool(cfg.num_experts) and i >= cfg.first_dense_layers,
                            q_chunk=q_chunk)
        aux = aux + a

    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params, cfg, x)
    if cfg.num_codebooks > 1:
        logits = logits.transpose(0, 1, 2, 3)  # (B,K,S,V)
    return logits, aux


def prefill(params, cfg: ArchConfig, tokens, cache_len: int,
            q_chunk: int = 1024):
    """Serving prefill: run the full prompt, return last-position logits and
    a decode-ready cache (ring-buffer KV / recurrent states).

    tokens: (B,S) or (B,K,S).  -> (logits (B,V)|(B,K,V), cache)."""
    kinds = cfg.layer_kinds()
    pre_idx, groups, suf_idx = _split_depth(cfg)
    x = embed_tokens(params, cfg, tokens)
    cache = {"prefix": [], "groups": [], "suffix": []}

    def moe_flag(i):
        return bool(cfg.num_experts) and i >= cfg.first_dense_layers

    for i, lp in zip(pre_idx, params["prefix"]):
        x, _, lc = _apply_layer(lp, cfg, kinds[i], x, moe_flag(i),
                                q_chunk=q_chunk, cache_len=cache_len)
        cache["prefix"].append(lc)

    if groups:
        pat = cfg.block_pattern

        def group_body(carry, gp):
            x, = carry
            lcs = []
            for j, kind in enumerate(pat):
                x, _, lc = _apply_layer(gp[j], cfg, kind, x,
                                        moe_flag(len(pre_idx) + j),
                                        q_chunk=q_chunk, cache_len=cache_len)
                lcs.append(lc)
            return (x,), tuple(lcs)

        (x,), gcaches = jax.lax.scan(group_body, (x,),
                                     tuple(params["groups"]))
        cache["groups"] = list(gcaches)

    for i, lp in zip(suf_idx, params["suffix"]):
        x, _, lc = _apply_layer(lp, cfg, kinds[i], x, moe_flag(i),
                                q_chunk=q_chunk, cache_len=cache_len)
        cache["suffix"].append(lc)

    x = rmsnorm(params["final_norm"], x[:, -1:])
    logits = unembed(params, cfg, x)
    if cfg.num_codebooks > 1:
        return logits[:, :, 0], cache
    return logits[:, 0], cache


# --------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------- #
def _init_layer_cache(cfg: ArchConfig, kind, batch, max_len, dtype):
    if kind in (ATTN, LOCAL):
        return init_attn_cache(cfg, batch, max_len, kind, dtype)
    if kind == MAMBA:
        return init_mamba_cache(cfg, batch)
    if kind == RGLRU:
        return init_rglru_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    pre_idx, groups, suf_idx = _split_depth(cfg)
    kinds = cfg.layer_kinds()
    cache: Dict[str, Any] = {
        "prefix": [_init_layer_cache(cfg, kinds[i], batch, max_len, dtype)
                   for i in pre_idx],
        "suffix": [_init_layer_cache(cfg, kinds[i], batch, max_len, dtype)
                   for i in suf_idx],
        "groups": [],
    }
    if groups:
        for j, kind in enumerate(cfg.block_pattern):
            one = _init_layer_cache(cfg, kind, batch, max_len, dtype)
            cache["groups"].append(
                jax.tree.map(lambda x: jnp.broadcast_to(x, (groups,) + x.shape), one))
    return cache


def _decode_layer(lp, cfg, kind, x, lcache, step):
    h = rmsnorm(lp["ln1"], x)
    if kind in (ATTN, LOCAL):
        dec = mla_decode if cfg.use_mla else attn_decode
        y, lcache = dec(lp["attn"], cfg, h, lcache, step, kind)
        x = x + y
        h2 = rmsnorm(lp["ln2"], x)
        if "moe" in lp:
            y2, _ = moe_forward(lp["moe"], cfg, h2)
        else:
            y2 = mlp(lp["mlp"], h2, cfg.activation)
        x = x + y2
    elif kind == MAMBA:
        y, lcache = mamba_decode(lp["mamba"], cfg, h, lcache, step)
        x = x + y
    elif kind == RGLRU:
        y, lcache = rglru_decode(lp["rglru"], cfg, h, lcache, step)
        x = x + y
        x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x), cfg.activation)
    return x, lcache


def decode_step(params, cache, cfg: ArchConfig, tokens, step):
    """One-token decode.  tokens: (B,) or (B,K) audio; step: scalar int32
    absolute position.  Returns (logits (B,V)|(B,K,V), new_cache)."""
    kinds = cfg.layer_kinds()
    pre_idx, groups, suf_idx = _split_depth(cfg)
    tok = tokens[:, None] if cfg.num_codebooks == 1 else tokens[..., None]
    x = embed_tokens(params, cfg, tok)                 # (B,1,D)
    new_cache = {"prefix": [], "groups": [], "suffix": []}

    for i, lp, lc in zip(pre_idx, params["prefix"], cache["prefix"]):
        x, lc = _decode_layer(lp, cfg, kinds[i], x, lc, step)
        new_cache["prefix"].append(lc)

    if groups:
        pat = cfg.block_pattern

        def group_body(carry, xs):
            x, = carry
            gp, gc = xs
            ncs = []
            for j, kind in enumerate(pat):
                x, nc = _decode_layer(gp[j], cfg, kind, x, gc[j], step)
                ncs.append(nc)
            return (x,), tuple(ncs)

        (x,), gcaches = jax.lax.scan(
            group_body, (x,), (tuple(params["groups"]), tuple(cache["groups"])))
        new_cache["groups"] = list(gcaches)

    for i, lp, lc in zip(suf_idx, params["suffix"], cache["suffix"]):
        x, lc = _decode_layer(lp, cfg, kinds[i], x, lc, step)
        new_cache["suffix"].append(lc)

    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params, cfg, x)
    if cfg.num_codebooks > 1:
        return logits[:, :, 0], new_cache                # (B,K,V)
    return logits[:, 0], new_cache                       # (B,V)


# --------------------------------------------------------------------- #
# sharding specs
# --------------------------------------------------------------------- #
_COL = {"wq", "wk", "wv", "wg", "wu", "in_proj", "w_x", "w_gate"}       # (D, out*tp)
_ROW = {"wo", "wd", "out_proj", "w_out"}                                # (in*tp, D)
_VEC_TP = {"bq", "bk", "bv", "conv_b", "b_a", "b_i", "dt_bias", "D", "lam"}
_REPL = {"ln1", "ln2", "final_norm", "qnorm", "knorm", "q_norm", "kv_norm",
         "A_log_unused"}


def _base_spec(path, name, audio, tp, fsdp, ep, shard_experts):
    """Sharding rules (DESIGN.md §5, EXPERIMENTS.md §Perf for measured
    comparisons).

    tp   — tensor-parallel axis: heads / d_ff / vocab ('model')
    fsdp — contracting-dim (ZeRO-style) axis for dense weights; used by
           grok's fsdp_tp scheme with unrolled layers (per-layer gathers)
    ep   — expert-parallel axis for MoE expert weights (deepseek's ep_tp)
    """
    # shared-expert MLPs under moe/shared are plain 2-D mlps, not (E,.,.)
    in_moe = any(getattr(k, "key", None) == "moe" for k in path) and \
        not any(getattr(k, "key", None) == "shared" for k in path)
    if name == "embed":
        base = (None, tp, fsdp) if audio else (tp, fsdp)
    elif name == "lm_head":
        base = (None, fsdp, tp) if audio else (fsdp, tp)
    elif in_moe and name in ("wg", "wu"):
        if ep and shard_experts:
            base = (ep, None, tp)
        elif ep:                      # E not divisible by ep: split d_ff 2-D
            base = (None, None, (ep, tp))
        elif fsdp:
            base = (None, fsdp, tp)
        else:
            base = (tp, None, None) if shard_experts else (None, None, tp)
    elif in_moe and name == "wd":
        if ep and shard_experts:
            # (ep, None, tp): contract d_ff locally, shard the output D —
            # swaps the per-layer f32 all-reduce of (E,cap,D) partials for
            # a smaller bf16 all-gather (§Perf pair 2, iter 1)
            base = (ep, None, tp)
        elif ep:
            base = (None, (ep, tp), None)
        elif fsdp:
            base = (None, tp, fsdp)
        else:
            base = (tp, None, None) if shard_experts else (None, tp, None)
    elif name == "router":
        base = (None, None)
    elif name in _COL:
        base = (fsdp, tp)
    elif name in _ROW:
        base = (tp, fsdp)
    elif name in ("wq_a", "wkv_a"):
        base = (fsdp, None)
    elif name in ("wq_b", "wkv_b", "dt_proj", "w_a", "w_i"):
        base = (None, tp)
    elif name in ("x_proj", "A_log"):
        base = (tp, None)
    elif name == "conv_w":
        base = (None, tp)
    elif name in _VEC_TP:
        base = (tp,)
    else:
        base = (None,)
    return base


def param_specs(params, cfg: ArchConfig, *, tp="model", fsdp=None,
                stack_axis=None, leading=(), tp_size=16, ep_size=16):
    """PartitionSpec tree mirroring ``params``.

    tp      — mesh axis for tensor parallelism (heads / d_ff / vocab)
    fsdp    — expert-parallel mesh axis for MoE weights (mode B: 'data')
    stack_axis — shard the layer-stack dim of scanned group params (weight
              streaming: per-layer gathers are loop-VARIANT so XLA cannot
              hoist them into a full-size buffer — grok's scheme)
    leading — mesh axes stamped on the first len(leading) leaf dims; FL mode A
              uses ('pod','data') for (cluster, client) dims, mode B ('pod',)
    """
    ep = fsdp if cfg.shard_scheme == "ep_tp" else None
    dense_fsdp = fsdp if cfg.shard_scheme == "fsdp_tp" else None
    shard_experts = bool(cfg.num_experts) and (
        (ep and ep_size and cfg.num_experts % ep_size == 0)
        or (not ep and not dense_fsdp and tp_size
            and cfg.num_experts % tp_size == 0))

    def spec(path, leaf):
        name = None
        for k in reversed(path):
            kk = getattr(k, "key", None)
            if isinstance(kk, str):
                name = kk
                break
        base = list(_base_spec(path, name, cfg.num_codebooks > 1, tp,
                               dense_fsdp, ep, shard_experts))
        while len(base) < leaf.ndim:
            base.insert(0, None)
        base = base[:leaf.ndim]
        if stack_axis and path and getattr(path[0], "key", None) == "groups":
            g = len(leading)
            if g < leaf.ndim and base[g] is None:
                base[g] = stack_axis
        for i, ax in enumerate(leading):
            if i < leaf.ndim and base[i] is None:
                base[i] = ax
        return P(*base)

    return jax.tree_util.tree_map_with_path(spec, params)


def cache_specs(cache, *, batch_axis="data", kv_axis=None, seq_axis=None,
                state_axis=None, attn_seq_axis=None):
    """Sharding specs for decode caches.

    batch_axis    — cache batch dim (None for long_500k's batch=1)
    kv_axis       — KV-head dim of attention caches (when divisible)
    seq_axis      — sequence dim of MLA latent caches (context parallelism)
    state_axis    — channel dim of SSM/LRU states ('model')
    attn_seq_axis — sequence dim of attention K/V caches when the KV-head
                    count does not divide the model axis (qwen kv=40,
                    grok/granite/chameleon kv=8): context parallelism
    """
    def spec(path, leaf):
        name = None
        for k in reversed(path):
            kk = getattr(k, "key", None)
            if isinstance(kk, str):
                name = kk
                break
        stacked = bool(path) and getattr(path[0], "key", None) == "groups"
        off = 1 if stacked else 0                # leading scan-group dim
        base = [None] * leaf.ndim
        if name == "pos":
            return P(*base)
        if leaf.ndim > off:
            base[off] = batch_axis               # batch dim
        if name in ("k", "v") and leaf.ndim == off + 4:
            base[off + 2] = kv_axis
            if kv_axis is None and attn_seq_axis is not None:
                base[off + 1] = attn_seq_axis
        elif name in ("ckv", "krope") and leaf.ndim == off + 3:
            base[off + 1] = seq_axis
        elif name == "h":
            base[off + 1] = state_axis           # (B, Di, N) or (B, W)
        elif name == "conv" and leaf.ndim == off + 3:
            base[off + 2] = state_axis           # (B, K-1, Di)
        return P(*base)

    return jax.tree_util.tree_map_with_path(spec, cache)
