"""Architecture configuration schema.

One frozen dataclass describes every assigned architecture (dense / MoE / SSM /
hybrid / VLM / audio).  The transformer substrate (transformer.py) consumes it;
configs/<id>.py instantiate it with the exact assigned hyperparameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

# Layer kinds usable in ``block_pattern`` (cycled over the depth).
ATTN = "attn"        # global causal attention (GQA/MQA/MHA or MLA)
LOCAL = "local"      # sliding-window causal attention (cfg.window)
MAMBA = "mamba"      # mamba-1 selective SSM block (attention-free)
RGLRU = "rglru"      # Griffin RG-LRU gated linear recurrence block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False               # chameleon-style qk layernorm
    attn_softcap: float = 0.0           # grok-style tanh logit cap
    rope_theta: float = 10000.0
    # --- mlp ---
    d_ff: int = 0
    activation: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)
    # --- layer pattern ---
    block_pattern: Tuple[str, ...] = (ATTN,)
    window: int = 0                     # width for LOCAL layers
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0                   # per-expert hidden width
    first_dense_layers: int = 0         # deepseek: leading dense layer(s)
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorbed: bool = False   # absorbed-matrix MLA decode (§Perf)
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    dt_rank: int = 0
    # --- hybrid (RG-LRU) ---
    lru_width: int = 0
    # --- audio ---
    num_codebooks: int = 1
    # --- embeddings / head ---
    tie_embeddings: bool = True
    emb_scale: bool = False             # gemma: scale embeddings by sqrt(d)
    # --- long-context serving variant ---
    sliding_variant_window: int = 0     # >0: long_500k uses this window
    # --- FL integration ---
    fl_mode: str = "fedavg_replica"     # fedavg_replica (A) | trust_fsdp (B)
    # --- mode-B weight sharding scheme (DESIGN.md §5) ---
    #   "tp"       1-D tensor parallel over 'model' (mode-A default)
    #   "ep_tp"    experts over 'data' + d_ff/heads over 'model' (deepseek)
    #   "stack_tp" layer-stack dim over 'data' (weight streaming) + TP (grok)
    shard_scheme: str = "tp"
    # unroll the layer loop instead of lax.scan — mode-B training needs
    # per-layer (unstacked) grad buffers so they shard; scan keeps the
    # stacked f32 accumulator unsharded inside the while body (measured:
    # 25.8 GB/buffer on grok — EXPERIMENTS.md §Perf)
    unroll_layers: bool = False
    # scan over layer INDICES with params captured (not scan-xs): per-layer
    # gathers are loop-variant (XLA cannot hoist them) and the cotangent
    # scatter-adds into a params-sharded buffer — compiles fast where
    # unrolling times out (grok train; EXPERIMENTS.md §Perf)
    scan_indexed: bool = False

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.arch_type == "ssm" and not self.dt_rank:
            object.__setattr__(self, "dt_rank", math.ceil(self.d_model / 16))
        if self.lru_width == 0 and RGLRU in self.block_pattern:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------ #
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind for the full depth, cycling block_pattern."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a multiple of 128 so the vocab
        dim shards over any mesh axis (TPU lane alignment); pad logits are
        masked to -inf in unembed."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def d_inner(self) -> int:           # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def attends(self) -> bool:
        return any(k in (ATTN, LOCAL) for k in self.layer_kinds())

    @property
    def subquadratic(self) -> bool:
        """True if no *global* attention layer exists (long_500k-capable
        natively) — LOCAL/MAMBA/RGLRU only."""
        return all(k != ATTN for k in self.layer_kinds())

    def long_context_variant(self) -> "ArchConfig":
        """Serving variant used for long_500k: swap global attention for
        sliding-window attention when the arch declares a window."""
        if self.subquadratic:
            return self
        if self.sliding_variant_window <= 0:
            raise ValueError(
                f"{self.name} is full-attention with no sliding-window "
                f"variant; long_500k is inapplicable (see DESIGN.md)")
        pat = tuple(LOCAL if k == ATTN else k for k in self.block_pattern)
        return dataclasses.replace(
            self, block_pattern=pat, window=self.sliding_variant_window)

    # -- parameter count (analytic, for rooflines: MODEL_FLOPS = 6 N D) -- #
    def param_count(self, active_only: bool = False) -> int:
        n = self.vocab_size * self.d_model * self.num_codebooks  # embed
        if not self.tie_embeddings:
            n += self.d_model * self.vocab_size * self.num_codebooks
        n += self.d_model  # final norm
        for kind in self.layer_kinds():
            n += self._layer_params(kind, active_only)
        return n

    def _layer_params(self, kind: str, active_only: bool) -> int:
        d = self.d_model
        n = 2 * d  # two rmsnorms (attn/mlp) or one+block norm
        if kind in (ATTN, LOCAL):
            if self.use_mla:
                rank_q = self.q_lora_rank or d
                qk = self.qk_nope_dim + self.qk_rope_dim
                if self.q_lora_rank:
                    n += d * self.q_lora_rank + self.q_lora_rank * self.num_heads * qk
                else:
                    n += d * self.num_heads * qk
                n += d * (self.kv_lora_rank + self.qk_rope_dim)
                n += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                n += self.num_heads * self.v_head_dim * d
            else:
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    n += self.q_dim + 2 * self.kv_dim
            n += self._mlp_params(active_only)
        elif kind == MAMBA:
            di, N, r = self.d_inner, self.ssm_state, self.dt_rank
            n += d * 2 * di + di * self.ssm_conv + di * (r + 2 * N)
            n += r * di + di * N + di + di * d
        elif kind == RGLRU:
            w = self.lru_width
            n += 2 * d * w + w * self.ssm_conv + 2 * w * w + 3 * w + w * d
            n += self._mlp_params(active_only)
        return n

    def _mlp_params(self, active_only: bool) -> int:
        d = self.d_model
        if self.num_experts:
            e_all = 3 * d * self.moe_d_ff
            n = d * self.num_experts                       # router
            n += self.num_shared_experts * e_all
            k = self.topk if active_only else self.num_experts
            n += k * e_all
            return n
        return 3 * d * self.d_ff
