from .config import ArchConfig, ATTN, LOCAL, MAMBA, RGLRU
from .transformer import (init_params, forward, prefill, decode_step,
                          init_cache, param_specs, cache_specs)
from .lm import lm_loss, weighted_lm_loss, xent

__all__ = [
    "ArchConfig", "ATTN", "LOCAL", "MAMBA", "RGLRU",
    "init_params", "forward", "prefill", "decode_step", "init_cache",
    "param_specs", "cache_specs", "lm_loss", "weighted_lm_loss", "xent",
]
