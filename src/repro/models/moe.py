"""Mixture-of-Experts block: top-k router + capacity-based scatter dispatch.

TPU-native design (see DESIGN.md §6): instead of the Mesh-TF (B,S,E,C)
dispatch einsum (whose dispatch tensor would be ~10^13 elements at our token
counts), tokens are flattened, assigned a position-in-expert via a cumsum over
a one-hot assignment matrix, and scattered into an (E*C, D) buffer that is
matmul'ed against expert weights with the expert dimension sharded over the
``model`` mesh axis.  Tokens past capacity are dropped (weighted residual
passthrough keeps them differentiable), matching GShard/Switch semantics.

Router load-balance auxiliary loss (Switch-style) is returned for training and
doubles as the per-client "learning quality" signal consumed by the digital
twin (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from .config import ArchConfig
from .modules import dense_init, mlp


def _constrain_ep(x, spec, cfg):
    """Pin expert-parallel sharding on dispatch tensors (ep_tp scheme only):
    keeps the (E, cap, D) buffers expert-sharded instead of letting GSPMD
    gather tokens globally (§Perf pair 2, iter 2)."""
    if cfg.shard_scheme != "ep_tp":
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        if not all(a is None or a in mesh.axis_names for a in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    kr, ke, ks = jax.random.split(key, 3)
    kg, ku, kd = jax.random.split(ke, 3)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": dense_init(kr, (D, E), scale=0.02, dtype=jnp.float32),
        "wg": dense_init(kg, (E, D, F), dtype=dtype),
        "wu": dense_init(ku, (E, D, F), dtype=dtype),
        "wd": dense_init(kd, (E, F, D), dtype=dtype),
    }
    if cfg.num_shared_experts:
        from .modules import init_mlp
        p["shared"] = init_mlp(ks, D, cfg.num_shared_experts * F, dtype=dtype)
    return p


def _dispatch_local(xt, e_flat, E, cap, dtype):
    """Capacity dispatch over one token shard: scatter tokens into an
    (E, cap, D) buffer; returns (buf, slot, keep)."""
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    keep = pos < cap
    slot = jnp.where(keep, e_flat * cap + pos, E * cap)       # overflow row
    K_rep = e_flat.shape[0] // xt.shape[0]
    x_rep = jnp.repeat(xt, K_rep, axis=0)
    buf = jnp.zeros((E * cap + 1, xt.shape[1]), dtype).at[slot].add(x_rep)
    return buf[:-1].reshape(E, cap, -1), slot, keep


def _ep_mesh_axes(cfg):
    if cfg.shard_scheme != "ep_tp":
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh and "data" in mesh.axis_names and "model" in mesh.axis_names:
            return mesh
    except Exception:
        pass
    return None


def moe_forward(p, cfg: ArchConfig, x):
    """x: (B, S, D) -> (y, aux) with Switch load-balance aux loss.

    Under the ep_tp scheme with an active mesh, dispatch/combine run inside
    ``shard_map`` with explicit ``all_to_all`` over the expert-parallel axis
    — the canonical EP exchange.  Measured on deepseek-v2 train_4k: replaces
    a 4 GB/layer token all-gather with a ~300 MB a2a (§Perf pair 2, iter 3).
    Capacity is enforced per token shard (cap_local = cap/|data|), the
    standard EP-system semantics.
    """
    B, S, D = x.shape
    E, K, F = cfg.num_experts, cfg.topk, cfg.moe_d_ff
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # Switch aux loss: E * <fraction routed to e> . <mean router prob e>
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    mesh = _ep_mesh_axes(cfg)

    if mesh is not None and E % mesh.shape["data"] == 0:
        from jax.experimental.shard_map import shard_map
        nd = mesh.shape["data"]
        cap_l = int(max(1, (T // nd) * K * cfg.capacity_factor // E))
        e_flat = gate_idx.reshape(T * K)

        def dispatch(xt_l, e_l):
            buf, slot, keep = _dispatch_local(xt_l, e_l, E, cap_l, x.dtype)
            # EP exchange: experts split over 'data', capacities concatenate
            buf = jax.lax.all_to_all(buf, "data", 0, 1, tiled=True)
            return buf, slot, keep                 # (E/nd, cap_l*nd, D)

        def combine(y_l, slot_l, keep_l, gv_l):
            y_l = jax.lax.all_to_all(y_l, "data", 1, 0, tiled=True)
            flat = y_l.reshape(E * cap_l, -1)
            y_tok = flat[jnp.minimum(slot_l, E * cap_l - 1)]
            y_tok = y_tok * (keep_l & (slot_l < E * cap_l))[:, None].astype(x.dtype)
            Tl = gv_l.shape[0]
            return (y_tok.reshape(Tl, K, -1) *
                    gv_l[..., None].astype(x.dtype)).sum(axis=1)

        buf, slot, keep = shard_map(
            dispatch, mesh=mesh,
            in_specs=(P("data", None), P("data")),
            out_specs=(P("data", None, None), P("data"), P("data")),
            check_vma=False)(xt, e_flat)

        h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["wu"])
        y_e = jnp.einsum("ecf,efd->ecd", h, p["wd"])
        y_e = _constrain_ep(y_e, ("data", None, "model"), cfg)

        y = shard_map(
            combine, mesh=mesh,
            in_specs=(P("data", None, "model"), P("data"), P("data"),
                      P("data", None)),
            out_specs=P("data", "model"),
            check_vma=False)(y_e, slot, keep, gate_vals)
    else:
        cap = int(max(1, (T * K * cfg.capacity_factor) // E))
        buf, slot, keep = _dispatch_local(
            xt, gate_idx.reshape(T * K), E, cap, x.dtype)
        buf = _constrain_ep(buf, ("data", None, None), cfg)
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["wu"])
        h = _constrain_ep(h, ("data", None, "model"), cfg)
        y_e = jnp.einsum("ecf,efd->ecd", h, p["wd"])          # (E, cap, D)
        y_e = _constrain_ep(y_e, ("data", None, "model"), cfg)
        y_tok = y_e.reshape(E * cap, D)[jnp.minimum(slot, E * cap - 1)]
        y_tok = y_tok * (keep & (slot < E * cap))[:, None].astype(x.dtype)
        y = (y_tok.reshape(T, K, D) *
             gate_vals[..., None].astype(x.dtype)).sum(axis=1)  # (T, D)

    if cfg.num_shared_experts:
        y = y + mlp(p["shared"], xt, cfg.activation)
    return y.reshape(B, S, D), aux
