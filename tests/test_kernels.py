"""Per-kernel allclose vs ref.py oracles, sweeping shapes and dtypes
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.kernels import (flash_attention, rglru_scan, selective_scan,
                           trust_aggregate, trust_aggregate_tree)
from repro.kernels import ref
from repro.kernels.trust_aggregate import trust_aggregate_global


@pytest.mark.parametrize("C,N,dtype", [
    (4, 1000, jnp.float32), (16, 8192, jnp.float32),
    (8, 20000, jnp.bfloat16), (2, 100, jnp.float32),
])
def test_trust_aggregate_sweep(C, N, dtype):
    key = jax.random.PRNGKey(C * N)
    x = jax.random.normal(key, (C, N)).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (C,)))
    got = trust_aggregate(x, w, interpret=True)
    want = ref.trust_aggregate_ref(x, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@given(st.integers(2, 12), st.integers(1, 11), st.integers(64, 3000))
@settings(max_examples=12, deadline=None)
def test_masked_trust_aggregate_matches_dense_on_valid_rows(C, valid, N):
    """Property: the masked kernel over a padded (C, N) client matrix equals
    the dense kernel over just the valid rows — padded rows, even filled
    with garbage, contribute exactly zero (the fused fixed-shape cluster
    round relies on this)."""
    valid = min(valid, C)
    key = jax.random.PRNGKey(C * 7919 + N)
    x = jax.random.normal(key, (C, N))
    # garbage in the padded rows must not leak into the aggregate
    x = x.at[valid:].set(1e30)
    mask = jnp.arange(C) < valid
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                         (valid,)))
    w_pad = jnp.zeros((C,)).at[:valid].set(w)
    got = trust_aggregate(x, w_pad, mask, interpret=True)
    want = trust_aggregate(x[:valid], w, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_masked_trust_aggregate_zeroes_nonzero_padded_weights():
    """The mask wins even when the caller forgot to zero padded weights."""
    x = jnp.ones((4, 256))
    w = jnp.full((4,), 0.25)
    mask = jnp.asarray([True, True, False, False])
    got = trust_aggregate(x, w, mask, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 0.5, atol=1e-7)


@given(st.integers(2, 10), st.integers(1, 9), st.integers(2, 6),
       st.integers(64, 3000))
@settings(max_examples=10, deadline=None)
def test_trust_aggregate_global_matches_two_step(C, valid, B, N):
    """Property: the fused Eqn-6+19 kernel equals the two-step reference —
    masked Eqn-6 aggregate, substituted into row c of the cluster stack,
    then the staleness-weighted average — for every cluster index c."""
    valid = min(valid, C)
    key = jax.random.PRNGKey(C * 31 + B * 7 + N)
    x = jax.random.normal(key, (C, N))
    mask = jnp.arange(C) < valid
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1),
                                         (C,))) * mask
    stack = jax.random.normal(jax.random.fold_in(key, 2), (B, N))
    gw = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3), (B,)))
    for c in (0, B - 1):
        got = trust_aggregate_global(x, w, mask, stack, gw, c,
                                     interpret=True)
        agg = trust_aggregate(x, w, mask, interpret=True)
        want = (gw[:, None] * stack.at[c].set(agg)).sum(0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_trust_aggregate_tree_matches_tree_average():
    from repro.core.trust import trust_weighted_average
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (4, 8, 16)),
            "b": jax.random.normal(key, (4, 5))}
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    got = trust_aggregate_tree(tree, w, interpret=True)
    want = trust_weighted_average(tree, w)
    for k in tree:
        np.testing.assert_allclose(got[k], want[k], atol=1e-5)


@pytest.mark.parametrize("B,S,H,d,window,softcap,dtype", [
    (1, 256, 2, 64, 0, 0.0, jnp.float32),
    (2, 512, 4, 64, 0, 0.0, jnp.float32),
    (1, 512, 2, 128, 128, 0.0, jnp.float32),      # sliding window
    (1, 256, 2, 64, 0, 30.0, jnp.float32),        # grok softcap
    (1, 256, 2, 64, 0, 0.0, jnp.bfloat16),
])
def test_flash_attention_sweep(B, S, H, d, window, softcap, dtype):
    key = jax.random.PRNGKey(S + H)
    q = (jax.random.normal(key, (B, S, H, d)) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, d)) * 0.3).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, d)).astype(dtype)
    got = flash_attention(q, k, v, bq=128, bk=128, window=window,
                          softcap=softcap, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window, softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,Di,N,bd,dtype", [
    (1, 32, 64, 8, 32, jnp.float32),
    (2, 64, 128, 16, 64, jnp.float32),
    (1, 48, 64, 8, 64, jnp.bfloat16),
])
def test_selective_scan_sweep(B, S, Di, N, bd, dtype):
    key = jax.random.PRNGKey(S)
    xc = (jax.random.normal(key, (B, S, Di)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, Di))).astype(dtype)
    Bc = (jax.random.normal(jax.random.fold_in(key, 2), (B, S, N)) * 0.5).astype(dtype)
    Cc = (jax.random.normal(jax.random.fold_in(key, 3), (B, S, N)) * 0.5).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (Di, N)))
    y, h = selective_scan(xc, dt, Bc, Cc, A, bd=bd, interpret=True)
    yr, hr = ref.selective_scan_ref(xc, dt, Bc, Cc, A)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=0.05)
    np.testing.assert_allclose(h, hr, atol=tol, rtol=0.05)


@pytest.mark.parametrize("B,S,W,bw,dtype", [
    (1, 32, 64, 64, jnp.float32),
    (2, 64, 256, 128, jnp.float32),
    (1, 64, 128, 128, jnp.bfloat16),
])
def test_rglru_scan_sweep(B, S, W, bw, dtype):
    key = jax.random.PRNGKey(W)
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W))).astype(dtype)
    bx = (jax.random.normal(jax.random.fold_in(key, 1), (B, S, W)) * 0.3).astype(dtype)
    y, h = rglru_scan(a, bx, bw=bw, interpret=True)
    yr, hr = ref.rglru_scan_ref(a, bx)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=0.05)
    np.testing.assert_allclose(h, hr, atol=tol, rtol=0.05)
