"""Unified federation API: spec round-trips, registries, engine parity
with the legacy entry points, scenario CLI, both execution scales."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
import repro.core as core
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec, legacy_spec)
from repro.data import dirichlet_partition, make_classification


def _data(n=1536, dim=48, devices=8, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    return data, dirichlet_partition(key, data.y, devices)


# --------------------------------------------------------------------- #
# spec <-> dict round-trip
# --------------------------------------------------------------------- #
def test_spec_dict_roundtrip():
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8, malicious_frac=0.25),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("krum", {"f": 1}),
        sim_seconds=5.0, seed=7)
    d = spec.to_dict()
    assert d["fleet"]["n_devices"] == 8
    assert FederationSpec.from_dict(d) == spec


def test_spec_from_dict_rejects_unknown_keys():
    with pytest.raises(KeyError, match="unknown keys"):
        FederationSpec.from_dict({"fleeet": {}})
    with pytest.raises(KeyError, match="unknown keys"):
        FederationSpec.from_dict({"fleet": {"n_devicez": 4}})


def test_spec_validate_rejects_unknown_components():
    with pytest.raises(KeyError, match="unknown aggregator"):
        FederationSpec(aggregator=AggregatorSpec("krummm")).validate()
    with pytest.raises(KeyError, match="unknown controller"):
        FederationSpec(controller=ControllerSpec("dqnn")).validate()


def test_spec_validate_rejects_scale_task_mismatch():
    with pytest.raises(ValueError, match="use task 'lm'"):
        FederationSpec(scale=api.DATACENTER_SCALE).validate()   # default mlp
    with pytest.raises(ValueError, match="use task 'mlp'"):
        FederationSpec(task=api.TaskSpec("lm")).validate()


def test_spec_validate_rejects_unimplemented_datacenter_components():
    base = FederationSpec(scale=api.DATACENTER_SCALE, task=api.TaskSpec("lm"))
    with pytest.raises(ValueError, match="not supported at datacenter"):
        base.replace(aggregator=AggregatorSpec("krum")).validate()
    with pytest.raises(ValueError, match="not implemented at datacenter"):
        base.replace(privacy=api.PrivacySpec(clip=1.0, noise=0.5)).validate()


def test_registry_decorator_and_lookup():
    from repro.api.registry import Registry
    reg = Registry("widget")

    @reg.register("foo")
    def make_foo(params):
        return ("foo", params)

    assert reg.get("foo")({"x": 1}) == ("foo", {"x": 1})
    assert "foo" in reg and reg.names() == ["foo"]
    with pytest.raises(KeyError, match="unknown widget"):
        reg.get("bar")
    with pytest.raises(ValueError, match="duplicate"):
        reg.register("foo")(make_foo)


def test_builtin_registries_populated():
    for name in ("trust", "fedavg", "krum", "multi_krum", "median",
                 "trimmed_mean"):
        assert name in api.AGGREGATORS
    for name in ("fixed", "dqn", "lyapunov"):
        assert name in api.CONTROLLERS
    for name in ("mlp", "lm"):
        assert name in api.TASKS
    for name in ("byzantine", "dp", "heterogeneous", "sync-baseline",
                 "lm-modeA"):
        assert name in api.SCENARIOS


# --------------------------------------------------------------------- #
# parity: spec-built federation == legacy AsyncFederation, bit for bit.
# Both entry points run DeviceScaleEngine, so this pins the *translation*
# (legacy_spec + the shim's controller mapping), not monolith-era numerics:
# a drift in either construction path breaks float equality here.
# --------------------------------------------------------------------- #
def test_spec_parity_with_legacy():
    data, parts = _data()
    cfg = core.AsyncFLConfig(n_devices=8, n_clusters=2, local_batch=32,
                             sim_seconds=5.0, seed=11)
    legacy = core.AsyncFederation(cfg, data, parts).run(eval_every=1.5)
    tr = Federation.from_spec(legacy_spec(cfg), data=data,
                              parts=parts).run(eval_every=1.5)
    assert legacy.times == tr.times
    assert legacy.accs == tr.accs          # float equality: bit-for-bit
    assert legacy.losses == tr.losses
    assert legacy.energies == tr.energies
    assert legacy.agg_counts == tr.agg_counts


def test_fused_round_parity_with_reference():
    """The fused jitted `FleetState` round reproduces the reference
    (pre-refactor-style eager, per-round host-sync) execution of the same
    round function at a fixed seed.  Scheduling (event times), chosen a_i,
    round/aggregation counters and accuracies match bit for bit; losses and
    energies are float32 reductions whose XLA-fused (FMA-contracted) form
    may differ from eager op-by-op dispatch in the last ulp, so they are
    pinned to ulp-level tolerance instead."""
    data, parts = _data(seed=9)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8, malicious_frac=0.25),
        clustering=api.ClusteringSpec(n_clusters=3),
        controller=ControllerSpec("fixed", {"a": 4}),
        sim_seconds=4.0, local_batch=32, seed=9)
    fused = Federation.from_spec(spec, data=data, parts=parts,
                                 fused=True).run(eval_every=1.0)
    ref = Federation.from_spec(spec, data=data, parts=parts,
                               fused=False).run(eval_every=1.0)
    assert len(fused.records) == len(ref.records) > 1
    # integer fields are bit-exact everywhere; float fields are observed
    # bit-exact on this CPU container but asserted at ulp tolerance so the
    # test stays meaningful on backends with different fusion contraction
    assert [r.a for r in fused.records] == [r.a for r in ref.records]
    assert fused.agg_counts == ref.agg_counts
    assert [r.cluster for r in fused.records] == \
           [r.cluster for r in ref.records]
    np.testing.assert_allclose(fused.times, ref.times, rtol=1e-6)
    np.testing.assert_allclose(fused.accs, ref.accs, atol=2e-3)
    np.testing.assert_allclose(fused.losses, ref.losses, rtol=5e-6)
    np.testing.assert_allclose(fused.energies, ref.energies, rtol=5e-6)


def test_fleet_state_is_device_resident_pytree():
    """FleetState is one flat pytree of arrays (jit-donatable): no Python
    scalars or host state hide inside."""
    data, parts = _data(seed=6)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 2}),
        sim_seconds=1.0, local_batch=32, seed=6)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    fed.run(eval_every=1.0)
    leaves = jax.tree.leaves(fed.engine.state)
    assert leaves and all(isinstance(l, jax.Array) for l in leaves)
    assert fed.engine.state.rep.shape == (8,)
    assert int(fed.engine.state.round) == fed.engine.agg_count > 0


def test_exact_shape_mode_drives_robust_aggregators():
    """Aggregators without mask support (krum-family rank statistics; the
    ±inf-padded sorts give median and trimmed_mean masked variants) run
    through the exact-shape jitted round and still produce a learning
    federation."""
    data, parts = _data(seed=7)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8, malicious_frac=0.25),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("multi_krum"),
        sim_seconds=3.0, local_batch=32, seed=7)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    assert not fed.engine._padded          # exact member shapes, no padding
    trace = fed.run(eval_every=1.0)
    assert trace.records and trace.accs[-1] > 0.2


def test_kernel_and_jnp_aggregation_agree():
    """The Pallas hot path and the jnp fallback build the same federation."""
    data, parts = _data(seed=2)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        sim_seconds=3.0, local_batch=32, seed=2)
    t_kernel = Federation.from_spec(
        spec.replace(aggregator=AggregatorSpec("trust", use_kernel=True)),
        data=data, parts=parts).run(eval_every=1.0)
    t_jnp = Federation.from_spec(
        spec.replace(aggregator=AggregatorSpec("trust", use_kernel=False)),
        data=data, parts=parts).run(eval_every=1.0)
    np.testing.assert_allclose(t_kernel.accs, t_jnp.accs, atol=1e-6)
    np.testing.assert_allclose(t_kernel.losses, t_jnp.losses, atol=1e-5)


# --------------------------------------------------------------------- #
# components through the facade
# --------------------------------------------------------------------- #
def test_robust_aggregator_scenario_runs():
    data, parts = _data(seed=3)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8, malicious_frac=0.25),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("median"),
        sim_seconds=3.0, local_batch=32, seed=3)
    trace = Federation.from_spec(spec, data=data, parts=parts).run(
        eval_every=1.0)
    assert trace.records and trace.accs[-1] > 0.2


def test_lyapunov_controller_respects_budget_pressure():
    """With a tiny budget the deficit queue builds and the greedy controller
    backs off to small a; with a huge budget it picks larger a."""
    ctx = api.ControllerCtx(round=5, cluster=0, obs=lambda: None,
                            cluster_loss=2.0, cluster_freq=1.0,
                            mean_freq=1.0, channel_good_frac=0.5,
                            energy_used=0.0)
    rich = api.LyapunovGreedyController(budget=1e6, horizon=10)
    poor = api.LyapunovGreedyController(budget=1.0, horizon=10)
    for _ in range(5):                      # build up the deficit queue
        poor.observe(ctx, consumed=10.0, loss=2.0)
    assert rich.select(ctx) >= poor.select(ctx)
    assert poor.select(ctx) == 1


def test_dp_privacy_spec_applies_noise():
    data, parts = _data(seed=4)
    base = FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 2}),
        sim_seconds=2.0, local_batch=32, seed=4)
    clean = Federation.from_spec(base, data=data, parts=parts).run()
    noisy = Federation.from_spec(
        base.replace(privacy=api.PrivacySpec(clip=1.0, noise=2.0)),
        data=data, parts=parts).run()
    assert clean.losses != noisy.losses     # DP path actually engaged


def test_datacenter_scale_runs_and_records():
    spec = FederationSpec(
        scale=api.DATACENTER_SCALE,
        fleet=FleetSpec(n_devices=4),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 1, "n_actions": 2}),
        task=api.TaskSpec("lm", {"seq": 8, "micro_batch": 2}),
        rounds=2)
    trace = Federation.from_spec(spec).run()
    assert len(trace.records) == 2
    assert all(np.isfinite(r.loss) for r in trace.records)
    assert trace.records[0].acc is None


# --------------------------------------------------------------------- #
# scenario CLI
# --------------------------------------------------------------------- #
def test_cli_spec_json_and_list(capsys):
    from repro.api import run as cli
    assert cli.main(["--list"]) == 0
    assert cli.main(["--scenario", "byzantine", "--spec-json"]) == 0
    out = capsys.readouterr().out
    assert '"malicious_frac": 0.25' in out


def test_cli_byzantine_end_to_end(capsys):
    from repro.api import run as cli
    rc = cli.main(["--scenario", "byzantine", "--sim-seconds", "2",
                   "--devices", "8", "--clusters", "2",
                   "--eval-every", "1.0"])
    assert rc == 0
    assert "summary:" in capsys.readouterr().out


def test_legacy_shim_exposes_engine_state():
    data, parts = _data(seed=5)
    cfg = core.AsyncFLConfig(n_devices=8, n_clusters=2, local_batch=32,
                             sim_seconds=2.0, malicious_frac=0.25, seed=5)
    fed = core.AsyncFederation(cfg, data, parts)
    fed.run(eval_every=1.0)
    assert fed.agg_count > 0 and fed.energy_used > 0
    assert fed.rep.shape == (8,) and fed.malicious.sum() == 2
