"""Cluster-major shard_map engine (`repro.api.cluster_engine`).

The contract under test: re-indexing the fleet cluster-major and running
the round as an explicit `jax.shard_map` changes *where* arrays live and
*how* the global average is reduced — never *what* the federation does.

* On a 1-device mesh the engine is bit-identical to the unsharded
  reference on every record field, across controllers, execution paths,
  faults, and uneven (auto-padded) memberships.
* On an 8-way forced-host mesh (subprocess) scheduling, actions and
  counters stay exact; float reductions are allclose (the Eqn-19 psum
  reassociates the sum).
* The lowered round contains zero all-gathers and at most two
  all-reduces — one packed metrics psum plus the Eqn-19 average.
* Checkpoints speak original device order: resumable state moves between
  the cluster-major and unsharded engines in both directions.
"""
import json
import logging
import os
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, FaultSpec,
                       Federation, FederationSpec, FleetSpec, ShardingSpec)
from repro.api.engine import DeviceScaleEngine, DeviceScaleGspmdEngine
from repro.data import dirichlet_partition, make_classification


def _data(n=512, dim=24, devices=8, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    return data, dirichlet_partition(key, data.y, devices)


def _spec(seed, mesh=(1,), impl=None, **kw):
    kw.setdefault("controller", ControllerSpec("fixed", {"a": 3}))
    # the cluster-major engine aggregates with the jnp oracle; the
    # unsharded reference must run the same rule for bit-exact parity
    kw.setdefault("aggregator", AggregatorSpec("trust",
                                               {"use_kernel": False}))
    kw.setdefault("fleet", FleetSpec(n_devices=8))
    kw.setdefault("clustering", api.ClusteringSpec(n_clusters=2))
    kw.setdefault("execution", "scanned")
    kw.setdefault("rounds", 6)
    kw.setdefault("sim_seconds", 1e9)
    return FederationSpec(local_batch=16, seed=seed,
                          sharding=ShardingSpec(mesh=mesh, impl=impl),
                          **kw)


def _records(trace):
    return [(r.t, r.round, r.cluster, r.a, r.loss, r.acc, r.energy,
             r.agg_count) for r in trace.records]


def _cluster_major(fed):
    from repro.api.cluster_engine import ClusterMajorEngine
    return isinstance(fed.engine, ClusterMajorEngine)


# --------------------------------------------------------------------- #
# routing + construction guards
# --------------------------------------------------------------------- #
def test_mesh_routes_to_cluster_major_gspmd_stays_selectable():
    data, parts = _data(seed=0)
    assert _cluster_major(Federation.from_spec(_spec(0), data=data,
                                               parts=parts))
    gspmd = Federation.from_spec(_spec(0, impl="gspmd"), data=data,
                                 parts=parts)
    assert not _cluster_major(gspmd)
    assert isinstance(gspmd.engine, DeviceScaleEngine)
    # the pinned registry scale resolves to the gspmd subclass
    assert api.ENGINES.get("device-gspmd") is DeviceScaleGspmdEngine


def test_rejects_unfused_and_unmasked_aggregators():
    data, parts = _data(seed=1)
    with pytest.raises(ValueError, match="fused-only"):
        Federation.from_spec(_spec(1), data=data, parts=parts, fused=False)
    with pytest.raises(ValueError, match="supports_mask=False"):
        Federation.from_spec(_spec(1, aggregator=AggregatorSpec("krum")),
                             data=data, parts=parts)


# --------------------------------------------------------------------- #
# 1-device mesh: bit-exact parity with the unsharded reference
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("ctl", [
    ControllerSpec("fixed", {"a": 3}),
    ControllerSpec("lyapunov", {"budget": 300.0, "horizon": 40}),
])
def test_scanned_trace_bit_identical(ctl):
    data, parts = _data(seed=31)
    plain = Federation.from_spec(
        _spec(31, mesh=(), controller=ctl), data=data, parts=parts).run()
    cm = Federation.from_spec(
        _spec(31, controller=ctl), data=data, parts=parts).run()
    assert _records(plain) == _records(cm)


def test_scanned_trace_bit_identical_dqn():
    from repro.api.components import DQNController
    ctl = DQNController.pretrain(seed=0, episodes=1, horizon=8)
    mk = lambda: DQNController(ctl.agent, ctl.cfg)
    data, parts = _data(seed=32)
    plain = Federation.from_spec(_spec(32, mesh=()), data=data,
                                 parts=parts, controller=mk()).run()
    cm = Federation.from_spec(_spec(32), data=data, parts=parts,
                              controller=mk()).run()
    assert _records(plain) == _records(cm)


def test_event_heap_trace_bit_identical():
    data, parts = _data(seed=33)
    kw = dict(execution="event", sim_seconds=2.0,
              controller=ControllerSpec("fixed", {"a": 2}))
    plain = Federation.from_spec(_spec(33, mesh=(), **kw), data=data,
                                 parts=parts).run(eval_every=1.0)
    cm = Federation.from_spec(_spec(33, **kw), data=data,
                              parts=parts).run(eval_every=1.0)
    assert _records(plain) == _records(cm)


def test_faulty_scanned_trace_bit_identical():
    faults = FaultSpec(dropout=0.25, straggler_frac=0.25,
                       straggler_factor=3.0, twin_spike_prob=0.2,
                       twin_spike_scale=4.0, seed=7)
    data, parts = _data(seed=34)
    plain = Federation.from_spec(_spec(34, mesh=(), faults=faults),
                                 data=data, parts=parts).run()
    cm = Federation.from_spec(_spec(34, faults=faults), data=data,
                              parts=parts).run()
    assert _records(plain) == _records(cm)


def test_uneven_membership_pads_logs_and_stays_bit_identical(caplog):
    """Uneven clusters force sentinel device slots even on a 1-device
    mesh (n_pad = C * max_cluster_size > n): the engine logs the padding
    it applied and the trace stays bit-identical."""
    from repro.api import registry

    data, parts = _data(seed=35)
    assign = np.array([0, 0, 0, 0, 0, 1, 1, 1], np.int32)  # sizes 5 + 3

    def build(mesh, impl=None):
        spec = _spec(35, mesh=mesh, impl=impl)
        ctl = registry.CONTROLLERS.get("fixed")({"a": 3})
        agg = registry.AGGREGATORS.get("trust")({"use_kernel": False})
        task = registry.TASKS.get(spec.task.kind)(spec.task.params)
        return DeviceScaleEngine.from_spec(
            spec, data=data, parts=parts, controller=ctl, aggregator=agg,
            task=task, assign=assign)

    plain = build(mesh=())
    with caplog.at_level(logging.INFO, logger="repro.cluster"):
        cm = build(mesh=(1,))
    assert any("cluster-major padding" in r.message for r in caplog.records)
    assert _records(plain.run_scanned(6)) == _records(cm.run_scanned(6))


# --------------------------------------------------------------------- #
# checkpoints: original device order at the boundary, both directions
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("src_mesh,dst_mesh", [((1,), ()), ((), (1,))])
def test_checkpoint_roundtrip_across_engines(src_mesh, dst_mesh):
    data, parts = _data(seed=36)
    straight = Federation.from_spec(_spec(36, mesh=src_mesh), data=data,
                                    parts=parts)
    a = _records(straight.engine.run_scanned(3, eval_final=False))
    b = _records(straight.engine.run_scanned(3))

    half = Federation.from_spec(_spec(36, mesh=src_mesh), data=data,
                                parts=parts)
    assert _records(half.engine.run_scanned(3, eval_final=False)) == a
    tree = half.engine.resumable_state()

    resumed = Federation.from_spec(_spec(36, mesh=dst_mesh), data=data,
                                   parts=parts)
    resumed.engine.restore_resumable(tree, rounds=half.engine.round,
                                     energy=half.engine.energy_used)
    assert _records(resumed.engine.run_scanned(3)) == b


# --------------------------------------------------------------------- #
# 8-way mesh (subprocess): parity + collective counts in the lowered HLO
# --------------------------------------------------------------------- #
_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import re
import jax
import jax.numpy as jnp
import numpy as np
import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec, ShardingSpec)
from repro.data import dirichlet_partition, make_classification

assert jax.device_count() == 8
key = jax.random.PRNGKey(41)
data = make_classification(key, n=512, dim=24)
parts = dirichlet_partition(key, data.y, 24)
spec = FederationSpec(
    fleet=FleetSpec(n_devices=24),
    clustering=api.ClusteringSpec(n_clusters=6),   # 6 % 8 != 0: auto-pad
    controller=ControllerSpec("lyapunov", {"budget": 300.0,
                                           "horizon": 40}),
    aggregator=AggregatorSpec("trust", {"use_kernel": False}),
    execution="scanned", rounds=6, sim_seconds=1e9,
    local_batch=16, seed=41)
rows = {}
for name, s in (("plain", spec),
                ("shard", spec.replace(
                    sharding=ShardingSpec(mesh=(8,))))):
    tr = Federation.from_spec(s, data=data, parts=parts).run()
    rows[name] = [[r.t, r.round, r.cluster, r.a, r.loss, r.energy,
                   r.agg_count] for r in tr.records]

# collective counts: defining call sites only (` op(`), never operand
# references (`%all-reduce.2` inside fusions)
eng = Federation.from_spec(
    spec.replace(sharding=ShardingSpec(mesh=(8,))), data=data,
    parts=parts).engine
txt = eng._build_event_fn().lower(
    eng.state, eng._ftbl, eng._ch3, jnp.int32(0), jnp.int32(3),
    *eng._statics).compile().as_text()
rows["hlo"] = {op: len(re.findall(rf" {op}\(", txt))
               for op in ("all-gather", "all-reduce", "all-to-all",
                          "collective-permute")}
print("CMPAR" + json.dumps(rows))
"""


def _run_subproc():
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.split("CMPAR", 1)[1])


@pytest.fixture(scope="module")
def subproc_rows():
    return _run_subproc()


def test_sharded8_parity_subprocess(subproc_rows):
    plain, shard = subproc_rows["plain"], subproc_rows["shard"]
    assert len(plain) == len(shard) == 7          # 6 rounds + final eval
    for p, s in zip(plain, shard):
        # t, round, cluster, a, loss, energy, agg_count
        assert p[1:4] == s[1:4] and p[6] == s[6]
        np.testing.assert_allclose([p[0], p[4], p[5]], [s[0], s[4], s[5]],
                                   rtol=1e-5, atol=1e-6)


def test_round_hlo_two_allreduce_zero_allgather(subproc_rows):
    """The whole point of the cluster-major layout: membership gathers
    are shard-local, so the only collectives the round lowers to are the
    packed metrics psum and the Eqn-19 global average."""
    hlo = subproc_rows["hlo"]
    assert hlo["all-gather"] == 0, hlo
    assert hlo["all-reduce"] <= 2, hlo
    assert hlo["all-to-all"] == 0 and hlo["collective-permute"] == 0, hlo
