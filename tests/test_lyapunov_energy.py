"""Lyapunov deficit queue (Eqn 12) and energy model (Eqns 7-8) tests."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.core import energy, lyapunov


class TestQueue:
    def test_evolution_matches_eqn12(self):
        q = lyapunov.init_queue(budget=10.0, horizon=10)
        q = lyapunov.step_queue(q, consumed=3.0)   # 3 - 1 = 2
        assert float(q.q) == 2.0
        q = lyapunov.step_queue(q, consumed=0.5)   # 2 + 0.5 - 1 = 1.5
        assert float(q.q) == 1.5

    @given(st.lists(st.floats(0, 5), min_size=1, max_size=50),
           st.floats(1.0, 100.0))
    @settings(max_examples=30, deadline=None)
    def test_queue_never_negative(self, consumptions, budget):
        q = lyapunov.init_queue(budget=budget, horizon=20)
        for c in consumptions:
            q = lyapunov.step_queue(q, c)
            assert float(q.q) >= 0.0

    def test_underspending_drains_queue(self):
        q = lyapunov.init_queue(budget=10.0, horizon=10)
        q = lyapunov.step_queue(q, 5.0)
        for _ in range(10):
            q = lyapunov.step_queue(q, 0.0)
        assert float(q.q) == 0.0

    def test_v_schedule_grows(self):
        assert lyapunov.v_schedule(10) > lyapunov.v_schedule(0)

    def test_reward_penalizes_backlog(self):
        q0 = lyapunov.init_queue(10.0, 10)
        q1 = q0._replace(q=jnp.asarray(5.0))
        r0 = lyapunov.drift_penalty_reward(2.0, 1.0, 1.0, q0, v=1.0)
        r1 = lyapunov.drift_penalty_reward(2.0, 1.0, 1.0, q1, v=1.0)
        assert float(r0) > float(r1)


class TestEnergy:
    def test_compute_energy_inverse_in_freq(self):
        e = energy.compute_energy(jnp.asarray([0.5, 1.0, 2.0]))
        assert e[0] > e[1] > e[2] > 0

    def test_comm_energy_worse_in_bad_channel(self):
        key = jax.random.PRNGKey(0)
        n = 256
        good = energy.comm_energy(jnp.zeros(n, jnp.int32), key)
        bad = energy.comm_energy(jnp.full((n,), 2, jnp.int32), key)
        assert float(bad.mean()) > float(good.mean())

    def test_channel_transition_stochastic(self):
        t = energy.channel_transition(0.7)
        np.testing.assert_allclose(np.asarray(t.sum(1)), 1.0, rtol=1e-6)
        key = jax.random.PRNGKey(1)
        s = jnp.zeros(2048, jnp.int32)
        s = energy.step_channel(key, s, t)
        frac_good = float((s == 0).mean())
        assert 0.6 < frac_good < 0.8
