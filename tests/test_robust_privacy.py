"""Robust-aggregation baselines and DP mechanism tests."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

import repro.core as core
from repro.core import robust, privacy
from repro.data import dirichlet_partition, make_classification

KEY = jax.random.PRNGKey(0)


def _clients_with_outlier(C=8, dim=32, outlier=50.0):
    honest = jax.random.normal(KEY, (C, dim)) * 0.1 + 1.0
    return {"w": honest.at[2].set(outlier)}


class TestRobust:
    def test_krum_rejects_outlier(self):
        tree = _clients_with_outlier()
        agg = robust.krum(tree, f=1)
        assert float(jnp.abs(agg["w"]).max()) < 5.0

    def test_multi_krum_rejects_outlier(self):
        tree = _clients_with_outlier()
        agg = robust.multi_krum(tree, f=1)
        assert float(jnp.abs(agg["w"]).max()) < 5.0

    def test_median_rejects_outlier(self):
        tree = _clients_with_outlier()
        agg = robust.coordinate_median(tree)
        assert float(jnp.abs(agg["w"]).max()) < 5.0

    def test_trimmed_mean_rejects_outlier(self):
        tree = _clients_with_outlier()
        agg = robust.trimmed_mean(tree, beta=0.2)
        assert float(jnp.abs(agg["w"]).max()) < 5.0

    def test_plain_mean_is_corrupted(self):
        """The vulnerability the robust rules (and trust weighting) fix."""
        tree = _clients_with_outlier()
        mean = jax.tree.map(lambda x: x.mean(0), tree)
        assert float(jnp.abs(mean["w"]).max()) > 5.0

    @given(st.integers(4, 10), st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_median_within_client_hull(self, C, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (C, 8))
        agg = robust.coordinate_median({"w": x})["w"]
        assert (np.asarray(agg) <= np.asarray(x.max(0)) + 1e-6).all()
        assert (np.asarray(agg) >= np.asarray(x.min(0)) - 1e-6).all()


class TestPrivacy:
    def test_clip_bounds_norm(self):
        upd = {"a": jnp.ones((10,)) * 3.0}
        clipped = privacy.clip_update(upd, clip_norm=1.0)
        n = float(jnp.linalg.norm(clipped["a"]))
        assert n <= 1.0 + 1e-5

    def test_small_update_unchanged(self):
        upd = {"a": jnp.ones((4,)) * 0.01}
        clipped = privacy.clip_update(upd, clip_norm=1.0)
        np.testing.assert_allclose(clipped["a"], upd["a"], rtol=1e-5)

    def test_noise_scale(self):
        agg = {"a": jnp.zeros((20000,))}
        out = privacy.add_gaussian_noise(KEY, agg, clip_norm=1.0,
                                         noise_multiplier=2.0, n_clients=4)
        std = float(out["a"].std())
        assert abs(std - 0.5) < 0.05          # sigma = 2*1/4

    def test_dp_federation_still_learns(self):
        key = jax.random.PRNGKey(1)
        data = make_classification(key, n=1024, dim=48)
        parts = dirichlet_partition(key, data.y, 6)
        cfg = core.AsyncFLConfig(n_devices=6, n_clusters=2, local_batch=32,
                                 sim_seconds=6.0, dp_clip=5.0, dp_noise=0.05)
        tr = core.AsyncFederation(cfg, data, parts).run(eval_every=2.0)
        assert tr.accs[-1] > 0.4


def test_robust_aggregator_in_federation_under_attack():
    key = jax.random.PRNGKey(2)
    data = make_classification(key, n=1024, dim=48)
    parts = dirichlet_partition(key, data.y, 8)
    base = dict(n_devices=8, n_clusters=2, local_batch=32, sim_seconds=5.0,
                malicious_frac=0.25, seed=2)
    accs = {}
    for agg in ("trust", "median"):
        cfg = core.AsyncFLConfig(aggregator=agg, **base)
        accs[agg] = core.AsyncFederation(cfg, data, parts).run(
            eval_every=2.0).accs[-1]
    assert accs["trust"] > 0.4 and accs["median"] > 0.4
