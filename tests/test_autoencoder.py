"""The federated autoencoder anomaly-detection workload: IoT telemetry
generator, AUC metric, learning dynamics, and engine-path integration."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec, TaskSpec)
from repro.api.registry import SCENARIOS
from repro.core.autoencoder import (anomaly_auc, init_mlp_autoencoder,
                                    reconstruction_errors,
                                    reconstruction_loss)
from repro.data import dirichlet_partition, make_iot_telemetry


def _spec(**kw):
    base = dict(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 5}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 1024, "dim": 16, "n_types": 4,
                       "latent": 2, "hidden": 32, "code": 4}),
        execution="scanned", rounds=40, sim_seconds=1e9,
        local_batch=32, lr=0.1, seed=0)
    base.update(kw)
    return FederationSpec(**base)


# --------------------------------------------------------------------- #
# telemetry generator
# --------------------------------------------------------------------- #
def test_telemetry_shapes_and_labels():
    d = make_iot_telemetry(jax.random.PRNGKey(0), n=1000, dim=12,
                           n_types=5, anomaly_frac=0.1)
    assert d.x.shape == (1000, 12)
    assert d.y.shape == d.device_type.shape == (1000,)
    assert d.y.dtype == d.device_type.dtype == jnp.int32
    assert set(np.unique(d.y)) <= {0, 1}
    assert set(np.unique(d.device_type)) <= set(range(5))
    frac = float(np.mean(np.asarray(d.y)))
    assert 0.05 < frac < 0.2               # ~Bernoulli(0.1)


def test_telemetry_anomalies_are_off_manifold():
    d = make_iot_telemetry(jax.random.PRNGKey(1), n=4000, dim=32,
                           anomaly_frac=0.1, spike=4.0)
    x, y = np.asarray(d.x), np.asarray(d.y).astype(bool)
    t = np.asarray(d.device_type)
    # anomalous samples sit farther from their family's centroid
    dists = np.empty(len(x))
    for fam in np.unique(t):
        m = t == fam
        dists[m] = np.linalg.norm(x[m] - x[m & ~y].mean(0), axis=1)
    assert dists[y].mean() > 1.5 * dists[~y].mean()


def test_device_type_partition_is_non_iid():
    d = make_iot_telemetry(jax.random.PRNGKey(2), n=2000, n_types=8)
    parts = dirichlet_partition(jax.random.PRNGKey(3), d.device_type, 8,
                                alpha=0.5, n_classes=8)
    idx = np.concatenate(parts)
    assert len(idx) == 2000 and len(set(idx.tolist())) == 2000
    t = np.asarray(d.device_type)
    dominant = [np.bincount(t[p], minlength=8).max() / len(p)
                for p in parts if len(p)]
    assert np.mean(dominant) > 0.25        # skewed vs the 1/8 uniform share


# --------------------------------------------------------------------- #
# AUC metric
# --------------------------------------------------------------------- #
def test_anomaly_auc_ordering():
    y = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)
    assert float(anomaly_auc(jnp.asarray([.1, .2, .3, .8, .9]), y)) == 1.0
    assert float(anomaly_auc(jnp.asarray([.9, .8, .7, .2, .1]), y)) == 0.0
    # ties get midrank credit
    assert float(anomaly_auc(jnp.ones(5), y)) == 0.5
    # a single-class eval set has no defined AUC
    assert np.isnan(float(anomaly_auc(jnp.ones(3), jnp.zeros(3, jnp.int32))))


def test_anomaly_auc_matches_naive_pair_count():
    key = jax.random.PRNGKey(4)
    s = jax.random.normal(key, (64,))
    y = jax.random.bernoulli(jax.random.PRNGKey(5), 0.3, (64,)).astype(
        jnp.int32)
    s_np, y_np = np.asarray(s), np.asarray(y)
    pos, neg = s_np[y_np == 1], s_np[y_np == 0]
    pairs = (pos[:, None] > neg[None, :]).mean() \
        + 0.5 * (pos[:, None] == neg[None, :]).mean()
    np.testing.assert_allclose(float(anomaly_auc(s, y)), pairs, atol=1e-6)


# --------------------------------------------------------------------- #
# the federated workload
# --------------------------------------------------------------------- #
def test_reconstruction_loss_decreases_and_detects():
    trace = Federation.from_spec(_spec()).run()
    rounds = [r for r in trace.records if r.acc is None]
    final = trace.records[-1]
    early = np.mean([r.loss for r in rounds[:5]])
    late = np.mean([r.loss for r in rounds[-5:]])
    assert late < 0.7 * early              # training actually reconstructs
    assert final.acc is not None and final.acc > 0.7   # detection AUC


def test_trust_aggregation_runs_padded_and_fused():
    fed = Federation.from_spec(_spec(rounds=3))
    assert fed.aggregator.supports_mask
    assert fed.engine._padded and fed.engine._fused_global
    trace = fed.engine.run_scanned(3)
    assert len(trace.records) == 4         # 3 rounds + final eval


def test_unsupervised_task_ignores_labels():
    task = Federation.from_spec(_spec(rounds=1)).task
    y = jnp.asarray([0, 1, 0], jnp.int32)
    np.testing.assert_array_equal(np.asarray(task.corrupt_labels(y)),
                                  np.asarray(y))
    params = init_mlp_autoencoder(jax.random.PRNGKey(0), dim=6, hidden=8,
                                  code=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 6))
    flipped = {"x": x, "y": 1 - jnp.zeros((10,), jnp.int32)}
    clean = {"x": x, "y": jnp.zeros((10,), jnp.int32)}
    assert float(reconstruction_loss(params, flipped)) \
        == float(reconstruction_loss(params, clean))
    assert reconstruction_errors(params, x).shape == (10,)


def test_scenario_is_registered():
    spec = SCENARIOS.get("autoencoder-anomaly")().validate()
    assert spec.task.kind == "autoencoder-anomaly"
    assert spec.execution == "scanned"
    assert spec.aggregator.kind == "trust"
