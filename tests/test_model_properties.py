"""Property tests on model-substrate invariants (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.models import ArchConfig, forward, init_params
from repro.models.attention import apply_rope, causal_mask
from repro.models.moe import moe_forward, init_moe

KEY = jax.random.PRNGKey(0)


class TestRoPE:
    @given(st.integers(0, 500), st.integers(1, 64))
    @settings(max_examples=15, deadline=None)
    def test_relative_property(self, offset, gap):
        """<R(p)q, R(p+g)k> depends only on the gap g, not on p."""
        q = jax.random.normal(KEY, (1, 1, 1, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 64))

        def score(p):
            qr = apply_rope(q, jnp.asarray([p]), 10000.0)
            kr = apply_rope(k, jnp.asarray([p + gap]), 10000.0)
            return float(jnp.sum(qr * kr))

        assert abs(score(0) - score(offset)) < 1e-3

    def test_norm_preserved(self):
        x = jax.random.normal(KEY, (2, 8, 4, 64))
        xr = apply_rope(x, jnp.arange(8), 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(xr), axis=-1), rtol=1e-4)


class TestCausality:
    def test_future_tokens_do_not_affect_past_logits(self):
        cfg = ArchConfig(name="c", arch_type="dense", num_layers=2,
                         d_model=64, vocab_size=128, num_heads=4,
                         num_kv_heads=2, d_ff=128)
        p = init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (1, 16), 0, 128)
        la, _ = forward(p, cfg, toks, remat=False)
        toks2 = toks.at[0, 12].set((toks[0, 12] + 7) % 128)
        lb, _ = forward(p, cfg, toks2, remat=False)
        # positions < 12 unchanged; position 12+ may change
        np.testing.assert_allclose(np.asarray(la[0, :12]),
                                   np.asarray(lb[0, :12]), atol=1e-5)
        assert float(jnp.abs(la[0, 12:] - lb[0, 12:]).max()) > 1e-6

    @given(st.integers(4, 32), st.integers(1, 16))
    @settings(max_examples=15, deadline=None)
    def test_causal_mask_lower_triangular(self, S, window):
        m = np.asarray(causal_mask(S, S, window=window))
        assert not np.triu(m, 1).any()                 # nothing above diag
        for i in range(S):
            lo = max(0, i - window + 1)
            assert m[i, lo:i + 1].all()
            assert not m[i, :lo].any()


class TestMoE:
    def test_aux_loss_minimal_for_balanced_router(self):
        """Uniform routing -> aux ~ 1 (the Switch loss's optimum)."""
        cfg = ArchConfig(name="m", arch_type="moe", num_layers=1, d_model=32,
                         vocab_size=64, num_heads=2, num_kv_heads=2, d_ff=64,
                         num_experts=4, topk=2, moe_d_ff=16)
        p = init_moe(jax.random.PRNGKey(3), cfg)
        # zero router weights => uniform probs => balanced
        p["router"] = jnp.zeros_like(p["router"])
        x = jax.random.normal(KEY, (2, 16, 32))
        _, aux = moe_forward(p, cfg, x)
        assert 0.9 < float(aux) < 1.3

    def test_capacity_drop_keeps_output_finite(self):
        cfg = ArchConfig(name="m", arch_type="moe", num_layers=1, d_model=32,
                         vocab_size=64, num_heads=2, num_kv_heads=2, d_ff=64,
                         num_experts=4, topk=2, moe_d_ff=16,
                         capacity_factor=0.25)      # aggressive dropping
        p = init_moe(jax.random.PRNGKey(4), cfg)
        x = jax.random.normal(KEY, (2, 16, 32))
        y, _ = moe_forward(p, cfg, x)
        assert bool(jnp.all(jnp.isfinite(y)))
