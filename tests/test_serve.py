"""repro.serve: bit-exact checkpointed resume, streamed JSONL traces, the
run-dir file protocol, and the service CLI.

The load-bearing guarantee is *segment parity*: running ``run_scanned(2K)``
straight equals running K rounds, checkpointing, rebuilding the federation
in a fresh object graph (standing in for a fresh process), restoring, and
running K more — record-for-record, including the float64 energy column.
"""
import json
import os

import jax
import numpy as np
import pytest

import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec, TaskSpec)
from repro.api.records import (JsonlSink, read_jsonl_trace, tail_jsonl)
from repro.checkpoint import load_checkpoint
from repro.data import dirichlet_partition, make_classification
from repro.serve import (SegmentRunner, latest_resumable, restore_resumable,
                         save_resumable, truncate_jsonl_trace,
                         verify_checkpoint)
from repro.serve.chaos import run_supervised
from repro.serve.service import RunDir, service_status


def _data(n=1536, dim=48, devices=8, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    return data, dirichlet_partition(key, data.y, devices)


def _spec(controller, seed=0):
    return FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=controller,
        execution="scanned", rounds=4, sim_seconds=1e9,
        local_batch=32, seed=seed)


CONTROLLERS = [
    ("fixed", {"a": 3}),
    ("lyapunov", {"budget": 120.0, "horizon": 40}),
    ("dqn", {"episodes": 2, "horizon": 10}),
]


# --------------------------------------------------------------------- #
# resume bit-parity (the tentpole invariant)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind,params", CONTROLLERS,
                         ids=[k for k, _ in CONTROLLERS])
def test_resume_bit_parity(tmp_path, kind, params):
    data, parts = _data(seed=1)
    spec = _spec(ControllerSpec(kind, dict(params)), seed=1)
    K = 4

    straight = Federation.from_spec(spec, data=data, parts=parts)
    want = straight.engine.run_scanned(2 * K, eval_final=False).records

    ckpt = str(tmp_path / "ckpts")
    fed1 = Federation.from_spec(spec, data=data, parts=parts)
    first = fed1.engine.run_scanned(K, eval_final=False).records
    save_resumable(fed1, ckpt, segment=1)

    # a fresh federation stands in for a fresh process: every leaf is
    # rebuilt from the spec, then overwritten from the checkpoint
    fed2 = Federation.from_spec(spec, data=data, parts=parts)
    manifest = restore_resumable(fed2, ckpt)
    assert manifest["rounds"] == K
    assert manifest["energy"] == fed1.engine.energy_used   # exact f64
    second = fed2.engine.run_scanned(K, eval_final=False).records

    got = first + second
    assert len(got) == len(want) == 2 * K
    for a, b in zip(want, got):
        assert a == b          # dataclass eq: every float compares exact


def test_checkpoint_roundtrips_fleetstate_leaves(tmp_path):
    """Every resumable leaf — including the typed PRNG-key — survives the
    npz round-trip with dtype and bits intact."""
    data, parts = _data(seed=2)
    spec = _spec(ControllerSpec("fixed", {"a": 2}), seed=2)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    fed.engine.run_scanned(3, eval_final=False)
    save_resumable(fed, str(tmp_path), segment=1)

    like = {"fleet": fed.engine.resumable_state()["fleet"],
            "times": fed.engine.scan_times,
            "policy": fed.controller.scan_policy().state}
    path, _ = latest_resumable(str(tmp_path))
    got = load_checkpoint(path, like)

    key_a, key_b = like["fleet"].key, got["fleet"].key
    assert jax.dtypes.issubdtype(key_b.dtype, jax.dtypes.prng_key)
    np.testing.assert_array_equal(np.asarray(jax.random.key_data(key_a)),
                                  np.asarray(jax.random.key_data(key_b)))
    for a, b in zip(jax.tree.leaves(like["fleet"])[:-1],
                    jax.tree.leaves(got["fleet"])[:-1]):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(like["times"]),
                                  np.asarray(got["times"]))


def test_runner_streams_identical_trace_across_resume(tmp_path):
    """trace.jsonl of stop-and-resume equals an uninterrupted segmented
    run's, byte for byte (per-segment eval records included)."""
    data, parts = _data(seed=3)
    spec = _spec(ControllerSpec("fixed", {"a": 2}), seed=3)

    def streamed(name, ckpt, federations):
        path = str(tmp_path / name)
        for i, fed in enumerate(federations):
            fed.engine.set_trace_sink(JsonlSink(path), retain=False)
            runner = SegmentRunner(fed, ckpt, segment_rounds=3)
            if i:
                runner.maybe_resume()
            runner.run_segment()
            fed.engine.trace_sink.close()
        return path

    a = streamed("a.jsonl", str(tmp_path / "ca"), [
        Federation.from_spec(spec, data=data, parts=parts)] * 2)
    b = streamed("b.jsonl", str(tmp_path / "cb"), [
        Federation.from_spec(spec, data=data, parts=parts),
        Federation.from_spec(spec, data=data, parts=parts)])
    with open(a) as fa, open(b) as fb:
        assert fa.read() == fb.read()
    trace = read_jsonl_trace(b)
    assert trace.n_records == 8            # 2 * (3 rounds + 1 eval)
    assert trace.records[-1].acc is not None


def test_retention_prunes_old_checkpoints(tmp_path):
    data, parts = _data(seed=4)
    spec = _spec(ControllerSpec("fixed", {"a": 1}), seed=4)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    runner = SegmentRunner(fed, str(tmp_path), segment_rounds=2, keep=2)
    for _ in range(4):
        runner.run_segment()
    files = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert files == ["ckpt_00000006.npz", "ckpt_00000008.npz"]
    assert latest_resumable(str(tmp_path))[1]["rounds"] == 8


def test_incomplete_checkpoint_is_skipped(tmp_path):
    """An npz without its manifest (crash between the two writes) must not
    be chosen for resume."""
    data, parts = _data(seed=5)
    spec = _spec(ControllerSpec("fixed", {"a": 1}), seed=5)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    runner = SegmentRunner(fed, str(tmp_path), segment_rounds=2)
    runner.run_segment()
    complete, _ = latest_resumable(str(tmp_path))
    with open(tmp_path / "ckpt_00000099.npz", "wb") as f:
        f.write(b"not a real checkpoint")    # no .json sidecar
    assert latest_resumable(str(tmp_path))[0] == complete


def test_corrupt_checkpoint_falls_back_to_verified(tmp_path):
    """A truncated npz (torn write / bit rot) fails its manifest CRC and
    resume silently falls back to the previous verified checkpoint."""
    data, parts = _data(seed=6)
    spec = _spec(ControllerSpec("fixed", {"a": 1}), seed=6)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    runner = SegmentRunner(fed, str(tmp_path), segment_rounds=2, keep=None)
    runner.run_segment()
    good, good_manifest = latest_resumable(str(tmp_path))
    runner.run_segment()
    newest, _ = latest_resumable(str(tmp_path))
    assert newest != good and verify_checkpoint(newest)

    # truncate the newest npz: manifest intact, bytes no longer match
    with open(newest, "r+b") as f:
        f.truncate(os.path.getsize(newest) // 2)
    assert not verify_checkpoint(newest)
    path, manifest = latest_resumable(str(tmp_path))
    assert path == good and manifest == good_manifest

    # restore actually loads the fallback (round counter proves which)
    fed2 = Federation.from_spec(spec, data=data, parts=parts)
    assert restore_resumable(fed2, str(tmp_path))["rounds"] == 2

    # pruning deletes the corrupt newest outright, keeps the verified one
    from repro.serve import prune_checkpoints
    prune_checkpoints(str(tmp_path), keep=2)
    assert not os.path.exists(newest)
    assert os.path.exists(good)


def test_legacy_manifest_without_digest_still_verifies(tmp_path):
    """Pre-digest manifests (no crc32 field) verify by existence, so old
    run dirs remain resumable."""
    data, parts = _data(seed=7)
    spec = _spec(ControllerSpec("fixed", {"a": 1}), seed=7)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    fed.engine.run_scanned(2, eval_final=False)
    npz = save_resumable(fed, str(tmp_path), segment=1)
    mpath = npz[:-len(".npz")] + ".json"
    manifest = json.load(open(mpath))
    for k in ("crc32", "bytes"):
        manifest.pop(k)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    assert verify_checkpoint(npz)
    assert latest_resumable(str(tmp_path))[0] == npz


def test_stale_pidfile_is_cleaned(tmp_path):
    """A SIGKILLed daemon leaves its pidfile; running_pid must treat the
    dead pid as not-running AND remove the stale file."""
    rd = RunDir(str(tmp_path)).ensure()
    with open(rd.path("serve.pid"), "w") as f:
        f.write("999999999")            # beyond pid_max: never alive
    assert rd.running_pid() is None
    assert not os.path.exists(rd.path("serve.pid"))


# --------------------------------------------------------------------- #
# JSONL plumbing
# --------------------------------------------------------------------- #
def test_truncate_jsonl_trace(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with open(path, "w") as f:
        for r in range(1, 7):
            f.write(json.dumps({"round": r, "loss": r * 0.5}) + "\n")
        f.write('{"round": 7, "los')           # torn tail from a crash
    assert truncate_jsonl_trace(path, 4) == 3  # rounds 5, 6 + torn line
    kept = [json.loads(l) for l in open(path)]
    assert [r["round"] for r in kept] == [1, 2, 3, 4]
    assert truncate_jsonl_trace(str(tmp_path / "missing.jsonl"), 4) == 0


def test_tail_jsonl_reads_only_the_tail(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with open(path, "w") as f:
        for r in range(200):
            f.write(json.dumps({"round": r}) + "\n")
    assert [d["round"] for d in tail_jsonl(path, n=5, block=64)] \
        == [195, 196, 197, 198, 199]
    assert tail_jsonl(str(tmp_path / "missing.jsonl")) == []


# --------------------------------------------------------------------- #
# the service CLI (in-process, --foreground)
# --------------------------------------------------------------------- #
def _tiny_spec_file(tmp_path):
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 2}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 512, "dim": 16, "n_types": 4,
                       "latent": 2, "hidden": 16, "code": 4,
                       "dirichlet_alpha": 5.0}),
        execution="scanned", rounds=3, sim_seconds=1e9,
        local_batch=16, lr=0.1, seed=11)
    path = tmp_path / "spec.json"
    path.write_text(json.dumps(spec.to_dict()))
    return str(path)


def test_service_cli_lifecycle(tmp_path, capsys):
    from repro.serve.__main__ import main
    run_dir = str(tmp_path / "run")
    spec_file = _tiny_spec_file(tmp_path)

    assert main(["start", "--run-dir", run_dir, "--spec-file", spec_file,
                 "--segment-rounds", "3", "--max-segments", "2",
                 "--foreground"]) == 0
    st = service_status(run_dir)
    assert not st["alive"]
    assert st["state"]["status"] == "stopped"
    assert st["state"]["rounds"] == 6
    assert st["latest_checkpoint"].endswith("ckpt_00000006.npz")

    # stopped service: `checkpoint` locates the newest checkpoint
    capsys.readouterr()
    assert main(["checkpoint", "--run-dir", run_dir]) == 0
    assert capsys.readouterr().out.strip() == st["latest_checkpoint"]

    # `start` refuses a run dir that already has checkpoints...
    assert main(["start", "--run-dir", run_dir, "--spec-file", spec_file,
                 "--foreground"]) == 1
    # ...and `resume` continues it (one more segment)
    assert main(["resume", "--run-dir", run_dir, "--segment-rounds", "3",
                 "--max-segments", "1", "--foreground"]) == 0
    st = service_status(run_dir)
    assert st["state"]["rounds"] == 9
    trace = read_jsonl_trace(os.path.join(run_dir, "trace.jsonl"))
    assert trace.n_records == 12          # 3 segments * (3 rounds + eval)
    assert [r.round for r in trace.records if r.acc is None] \
        == list(range(1, 10))

    # `stop` on a stopped service is a clean no-op
    assert main(["stop", "--run-dir", run_dir]) == 0
    # `resume` on an empty dir is a config error, not a traceback
    assert main(["resume", "--run-dir", str(tmp_path / "empty"),
                 "--foreground"]) == 1


# --------------------------------------------------------------------- #
# chaos: SIGKILL mid-segment, supervised recovery
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind,params", CONTROLLERS,
                         ids=[k for k, _ in CONTROLLERS])
def test_chaos_sigkill_recovery_trace_parity(tmp_path, monkeypatch,
                                             kind, params):
    """SIGKILL the service after a checkpoint lands (next segment in
    flight), let the supervisor restart it, and byte-compare the final
    trace.jsonl against an uninterrupted run of the same spec: recovery
    must be invisible in the output, for every controller."""
    import repro.serve
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(repro.serve.__file__))))
    monkeypatch.setenv(
        "PYTHONPATH",
        src + os.pathsep + os.environ.get("PYTHONPATH", ""))
    from repro.serve.__main__ import main

    spec = _spec(ControllerSpec(kind, dict(params)), seed=13)
    spec_file = str(tmp_path / "spec.json")
    with open(spec_file, "w") as f:
        json.dump(spec.to_dict(), f)

    # uninterrupted reference, in-process
    ref = str(tmp_path / "ref")
    assert main(["start", "--run-dir", ref, "--spec-file", spec_file,
                 "--segment-rounds", "2", "--max-segments", "3",
                 "--keep", "0", "--foreground"]) == 0

    # chaos run: subprocess children under the supervisor, one SIGKILL
    chaos = str(tmp_path / "chaos")
    summary = run_supervised(
        chaos, total_segments=3, segment_rounds=2, kills=1, keep=0,
        spec_file=spec_file, log=lambda *a, **k: None)
    assert summary["segments"] == 3
    assert summary["kills"] == 1
    assert summary["restarts"] >= 1

    with open(os.path.join(ref, "trace.jsonl")) as fa, \
            open(os.path.join(chaos, "trace.jsonl")) as fb:
        assert fa.read() == fb.read()
    st = service_status(chaos)
    assert not st["alive"]
    assert st["checkpoint_manifest"]["rounds"] == 6


def test_rundir_pid_and_requests(tmp_path):
    rd = RunDir(str(tmp_path)).ensure()
    assert rd.running_pid() is None
    rd.write_pid()
    assert rd.running_pid() == os.getpid()    # we are alive
    rd.clear_pid()
    assert rd.running_pid() is None
    assert not rd.take_request("stop.req")
    rd.request("stop.req")
    assert rd.take_request("stop.req")
    assert not rd.take_request("stop.req")    # consumed
