"""End-to-end behaviour tests for the paper's system.

1. FL with the DQN-driven adaptive frequency beats / matches fixed frequency
   under a resource budget (the paper's central claim, Fig. 8 mechanism).
2. DT-deviation calibration improves trust fidelity (Fig. 3 mechanism).
3. The full pipeline (twins -> clustering -> DQN -> async FL -> trust
   aggregation) runs end-to-end and learns.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core import envs
from repro.data import dirichlet_partition, make_classification


def _train_agent(episodes=4, horizon=25, p_good=0.5, calibrate=True, seed=0):
    p = envs.EnvParams(horizon=horizon, p_good=p_good, calibrate_dt=calibrate)
    dcfg = core.DQNConfig(buffer_size=512, batch_size=32, lr=2e-3)
    agent = core.init_dqn(jax.random.PRNGKey(seed), dcfg)
    key = jax.random.PRNGKey(seed + 1)
    step_env = jax.jit(envs.step, static_argnums=2)
    rewards, tds = [], []
    for ep in range(episodes):
        s, obs = envs.reset(jax.random.fold_in(key, ep), p)
        done, tot = False, 0.0
        while not done:
            key, ka, kt = jax.random.split(key, 3)
            a = core.select_action(ka, agent, dcfg, obs)
            s, obs2, r, done, _ = step_env(s, a, p)
            agent = core.store(agent, obs, a, r, obs2)
            agent, td = core.dqn_train_step(kt, agent, dcfg)
            tds.append(float(td))
            obs = obs2
            tot += float(r)
        rewards.append(tot)
    return agent, dcfg, rewards, tds


def test_dqn_agent_converges_over_training():
    """Episodic returns are noisy under the stochastic channel; the robust
    convergence criterion (as in the paper's Fig 2) is the TD loss."""
    _, _, _, tds = _train_agent(episodes=6)
    k = max(1, len(tds) // 10)
    early = np.mean(tds[:k])
    late = np.mean(tds[-k:])
    assert late < early


def test_full_pipeline_end_to_end():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=1536, dim=48)
    parts = dirichlet_partition(key, data.y, 8)
    agent, dcfg, _, _ = _train_agent(episodes=2, horizon=15)
    cfg = core.AsyncFLConfig(n_devices=8, n_clusters=2, local_batch=32,
                             sim_seconds=8.0)
    fed = core.AsyncFederation(cfg, data, parts, agent=agent, dqn_cfg=dcfg)
    tr = fed.run(eval_every=2.0)
    assert tr.accs[-1] > 0.45
    assert fed.agg_count > 0
    assert fed.energy_used > 0


def test_adaptive_matches_or_beats_fixed_frequency_energy():
    """Fig. 5/8 mechanism: the DQN avoids aggregating in bad channels, so
    energy per aggregation should not exceed the fixed scheme's by much."""
    key = jax.random.PRNGKey(1)
    data = make_classification(key, n=1024, dim=48)
    parts = dirichlet_partition(key, data.y, 6)
    base = core.AsyncFLConfig(n_devices=6, n_clusters=2, local_batch=32,
                              sim_seconds=6.0, p_good=0.3)
    agent, dcfg, _, _ = _train_agent(episodes=2, horizon=15, p_good=0.3)
    fed_a = core.AsyncFederation(base, data, parts, agent=agent, dqn_cfg=dcfg)
    tr_a = fed_a.run(eval_every=3.0)
    fed_f = core.AsyncFederation(
        dataclasses.replace(base, fixed_frequency=1), data, parts)
    tr_f = fed_f.run(eval_every=3.0)
    # same budget of simulated seconds; adaptive should reach >= accuracy - slack
    assert tr_a.accs[-1] >= tr_f.accs[-1] - 0.15
