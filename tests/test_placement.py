"""Placement layer: `ShardingSpec` round-trip + validation, `Placement`
resolution, the `Engine` protocol/registry, and sharded-vs-single-device
trace parity.

The 8-way mesh parity test runs in-process when this suite is launched
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
forced-8-device tier-1 job); on a plain single-device run the same check
goes through a subprocess that forces the device pool before importing
jax.
"""
import json
import os
import subprocess
import sys
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec, ShardingSpec)
from repro.api.placement import SINGLE_DEVICE, resolve
from repro.data import dirichlet_partition, make_classification


def _data(n=512, dim=24, devices=8, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    return data, dirichlet_partition(key, data.y, devices)


def _scan_spec(seed, mesh=(), impl=None, **kw):
    kw.setdefault("controller", ControllerSpec("fixed", {"a": 3}))
    return FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        execution="scanned", rounds=6, sim_seconds=1e9,
        local_batch=16, seed=seed,
        sharding=ShardingSpec(mesh=mesh, impl=impl), **kw)


# --------------------------------------------------------------------- #
# ShardingSpec: dict round-trip + validation
# --------------------------------------------------------------------- #
def test_sharding_spec_dict_roundtrip():
    spec = FederationSpec(sharding=ShardingSpec(mesh=(8,)))
    d = spec.to_dict()
    assert d["sharding"]["mesh"] == (8,)
    assert FederationSpec.from_dict(d) == spec
    # through JSON (tuples become lists; __post_init__ normalizes back)
    assert FederationSpec.from_dict(json.loads(json.dumps(d))) == spec
    two_d = ShardingSpec(mesh=[4, 2], axes=["cluster", "fleet"],
                         cluster_axis="cluster")
    assert two_d.mesh == (4, 2) and two_d.axes == ("cluster", "fleet")
    spec2 = FederationSpec(
        fleet=FleetSpec(n_devices=16),
        clustering=api.ClusteringSpec(n_clusters=4), sharding=two_d)
    assert FederationSpec.from_dict(
        json.loads(json.dumps(spec2.to_dict()))) == spec2


def test_sharding_spec_default_is_single_device():
    spec = FederationSpec()
    assert not spec.sharding.is_sharded
    spec.validate()                       # no mesh checks engaged
    assert resolve(spec.sharding, n_devices=16, n_clusters=4) \
        is SINGLE_DEVICE


def test_sharding_spec_validate_rejects_indivisible_mesh():
    # divisibility is a gspmd-impl constraint: the shard_map engine pads
    # indivisible fleets itself (see test_cluster_engine.py)
    with pytest.raises(ValueError, match="does not divide n_devices=16"):
        FederationSpec(
            sharding=ShardingSpec(mesh=(3,), impl="gspmd")).validate()
    with pytest.raises(ValueError, match="does not divide n_clusters=4"):
        FederationSpec(
            fleet=FleetSpec(n_devices=16),
            sharding=ShardingSpec(mesh=(8,), cluster_axis="fleet",
                                  device_axis=None,
                                  impl="gspmd")).validate()


def test_sharding_spec_validate_rejects_malformed_meshes():
    with pytest.raises(ValueError, match="names"):
        ShardingSpec(mesh=(4, 2), axes=("fleet",)).validate(16, 4)
    with pytest.raises(ValueError, match="duplicate"):
        ShardingSpec(mesh=(4, 2), axes=("x", "x"),
                     device_axis="x").validate(16, 4)
    with pytest.raises(ValueError, match="not a mesh axis"):
        ShardingSpec(mesh=(4,), axes=("pod",)).validate(16, 4)
    with pytest.raises(ValueError, match="no default axis names"):
        ShardingSpec(mesh=(2, 2, 2)).validate(16, 4)
    with pytest.raises(ValueError, match="distinct mesh axes"):
        # gspmd-only: the cluster-major shard_map engine deliberately
        # co-shards devices and clusters over the one mesh axis
        ShardingSpec(mesh=(4,), cluster_axis="fleet",
                     impl="gspmd").validate(16, 4)
    with pytest.raises(ValueError, match="not supported at datacenter"):
        FederationSpec(scale=api.DATACENTER_SCALE, task=api.TaskSpec("lm"),
                       sharding=ShardingSpec(mesh=(1,))).validate()


def test_resolve_rejects_oversized_mesh():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        resolve(ShardingSpec(mesh=(64,)), n_devices=64, n_clusters=4)


def test_cli_mesh_flag_errors_cleanly(capsys):
    """--mesh config errors (indivisible or oversized meshes) print
    `error: ...` and exit 2 — never a traceback."""
    from repro.api import run as cli
    assert cli.main(["--scenario", "byzantine", "--mesh", "3",
                     "--impl", "gspmd"]) == 2
    assert "does not divide" in capsys.readouterr().err
    assert cli.main(["--scenario", "byzantine", "--mesh", "64",
                     "--devices", "64"]) == 2
    assert "device" in capsys.readouterr().err
    assert cli.main(["--scenario", "byzantine", "--mesh", "x"]) == 2
    assert "expected a mesh shape" in capsys.readouterr().err


# --------------------------------------------------------------------- #
# Placement leaf groups
# --------------------------------------------------------------------- #
class _MiniState(NamedTuple):
    twins: dict
    rep: jnp.ndarray
    channel: jnp.ndarray
    cluster_params: dict
    global_params: dict
    cluster_ts: jnp.ndarray
    queue: jnp.ndarray
    round: jnp.ndarray
    key: jnp.ndarray


def test_placement_leaf_groups_and_axes():
    pl = resolve(ShardingSpec(mesh=(1,)), n_devices=8, n_clusters=2)
    assert pl.is_sharded and pl.device_axis == "fleet"
    assert pl.cluster_axis is None        # 1-D default: replicate clusters
    state = _MiniState(
        twins={"loss": jnp.zeros(8)}, rep=jnp.ones(8),
        channel=jnp.zeros(8, jnp.int32),
        cluster_params={"w": jnp.zeros((2, 3))},
        global_params={"w": jnp.zeros(3)}, cluster_ts=jnp.zeros(2),
        queue=jnp.zeros(()), round=jnp.zeros((), jnp.int32),
        key=jax.random.PRNGKey(0))
    sh = pl.state_shardings(state)
    assert sh.rep.spec == jax.sharding.PartitionSpec("fleet")
    assert sh.twins["loss"].spec == jax.sharding.PartitionSpec("fleet")
    assert sh.cluster_params["w"].spec == jax.sharding.PartitionSpec()
    assert sh.queue.spec == jax.sharding.PartitionSpec()
    pl2 = resolve(ShardingSpec(mesh=(1, 1)), n_devices=8, n_clusters=2)
    assert pl2.cluster_axis == "cluster"  # 2-D default: cluster-major mesh
    assert pl2.state_shardings(state).cluster_params["w"].spec == \
        jax.sharding.PartitionSpec("cluster")


# --------------------------------------------------------------------- #
# Engine protocol + registry
# --------------------------------------------------------------------- #
def test_engines_registry_and_protocol():
    assert set(api.ENGINES.names()) >= {"device", "datacenter"}
    for name in ("device", "datacenter"):
        cls = api.ENGINES.get(name)
        assert hasattr(cls, "from_spec") and hasattr(cls, "run")
        assert hasattr(cls, "run_scanned")
    with pytest.raises(KeyError, match="unknown engine"):
        FederationSpec(scale="warp").validate()


def test_custom_engine_registration_routes_scale():
    """`scale` is a registry key: a third-party engine class is reachable
    from a spec without touching the `Federation` facade."""
    from repro.api.records import FLTrace, RoundRecord

    @api.register_engine("toy-sim")
    class ToyEngine:
        def __init__(self, spec):
            self.spec = spec

        @classmethod
        def from_spec(cls, spec, *, controller, aggregator, task,
                      data=None, parts=None, fused=None):
            return cls(spec)

        def run(self, eval_every=1.0, max_rounds=None):
            t = FLTrace()
            t.append(RoundRecord(t=0.0, round=1, cluster=0, a=1, loss=0.5,
                                 acc=None, energy=0.0, agg_count=1))
            return t

        def run_scanned(self, K, *, eval_final=True):
            raise ValueError("toy engine has no scanned lowering")

    spec = FederationSpec(scale="toy-sim",
                          controller=ControllerSpec("fixed", {"a": 1}))
    fed = Federation.from_spec(spec)
    assert isinstance(fed.engine, api.Engine)     # structural protocol
    assert fed.run().records[0].loss == 0.5


def test_datacenter_engine_rejects_run_scanned():
    spec = FederationSpec(
        scale=api.DATACENTER_SCALE, fleet=FleetSpec(n_devices=4),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 1, "n_actions": 2}),
        task=api.TaskSpec("lm", {"seq": 8, "micro_batch": 2}), rounds=1)
    fed = Federation.from_spec(spec)
    with pytest.raises(ValueError, match="no scanned lowering"):
        fed.engine.run_scanned(2)


# --------------------------------------------------------------------- #
# parity: explicit 1-device mesh == single-device fallback, bit for bit
# --------------------------------------------------------------------- #
def _record_tuples(trace):
    return [(r.t, r.round, r.cluster, r.a, r.loss, r.acc, r.energy,
             r.agg_count) for r in trace.records]


def test_one_device_mesh_trace_bit_identical():
    """The sharded jit path (in_shardings/out_shardings over an explicit
    1-device mesh) reproduces the default single-device scanned trace bit
    for bit — placement changes *where*, never *what*."""
    data, parts = _data(seed=21)
    plain = Federation.from_spec(_scan_spec(21), data=data,
                                 parts=parts).run()
    meshed = Federation.from_spec(
        _scan_spec(21, mesh=(1,), impl="gspmd"), data=data,
        parts=parts).run()
    assert _record_tuples(plain) == _record_tuples(meshed)


def test_one_device_mesh_event_heap_bit_identical():
    data, parts = _data(seed=22)
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 2}),
        sim_seconds=2.0, local_batch=16, seed=22)
    plain = Federation.from_spec(spec, data=data, parts=parts).run(
        eval_every=1.0)
    meshed = Federation.from_spec(
        spec.replace(sharding=ShardingSpec(mesh=(1,), impl="gspmd")),
        data=data, parts=parts).run(eval_every=1.0)
    assert _record_tuples(plain) == _record_tuples(meshed)


# --------------------------------------------------------------------- #
# parity: 8-way host mesh vs unsharded — exact on scheduling/counters,
# ulp on float reductions
# --------------------------------------------------------------------- #
def _assert_sharded_parity(plain, shard):
    assert [r.cluster for r in plain.records] == \
           [r.cluster for r in shard.records]
    assert [r.a for r in plain.records] == [r.a for r in shard.records]
    assert [r.round for r in plain.records] == \
           [r.round for r in shard.records]
    assert [r.agg_count for r in plain.records] == \
           [r.agg_count for r in shard.records]
    for field in ("t", "loss", "energy"):
        np.testing.assert_allclose(
            [getattr(r, field) for r in plain.records],
            [getattr(r, field) for r in shard.records],
            rtol=1e-5, atol=1e-6, err_msg=field)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8 (the CI forced-8 job)")
def test_sharded_scanned_parity_inprocess():
    data, parts = _data(seed=23)
    spec = _scan_spec(23, controller=ControllerSpec(
        "lyapunov", {"budget": 300.0, "horizon": 40}))
    plain = Federation.from_spec(spec, data=data, parts=parts).run()
    shard = Federation.from_spec(
        spec.replace(sharding=ShardingSpec(mesh=(8,), impl="gspmd")),
        data=data, parts=parts).run()
    _assert_sharded_parity(plain, shard)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
import repro.api as api
from repro.api import (ControllerSpec, Federation, FederationSpec,
                       FleetSpec, ShardingSpec)
from repro.api.components import DQNController
from repro.data import dirichlet_partition, make_classification

assert jax.device_count() == 8
key = jax.random.PRNGKey(23)
data = make_classification(key, n=512, dim=24)
parts = dirichlet_partition(key, data.y, 8)
spec = FederationSpec(
    fleet=FleetSpec(n_devices=8),
    clustering=api.ClusteringSpec(n_clusters=2),
    controller=ControllerSpec("fixed", {"a": 3}),     # overridden below
    execution="scanned", rounds=6, sim_seconds=1e9,
    local_batch=16, seed=23)
# the adaptive (DQN) controller, trained once and shared by both runs
ctl = DQNController.pretrain(seed=0, episodes=1, horizon=8)
mk = lambda: DQNController(ctl.agent, ctl.cfg)
rows = {}
for name, s in (("plain", spec),
                ("shard", spec.replace(
                    sharding=ShardingSpec(mesh=(8,), impl="gspmd")))):
    tr = Federation.from_spec(s, data=data, parts=parts,
                              controller=mk()).run()
    rows[name] = [[r.t, r.round, r.cluster, r.a, r.loss, r.energy,
                   r.agg_count] for r in tr.records]
print("PARITY" + json.dumps(rows))
"""


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="covered in-process by "
                           "test_sharded_scanned_parity_inprocess")
def test_sharded_scanned_parity_subprocess():
    """Single-device suites still pin the 8-way mesh: a subprocess forces
    the host device pool before importing jax and runs the adaptive
    (DQN-controlled) scanned scenario both ways."""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    payload = out.stdout.split("PARITY", 1)[1]
    rows = json.loads(payload)
    plain, shard = rows["plain"], rows["shard"]
    assert len(plain) == len(shard) == 7          # 6 rounds + final eval
    for p, s in zip(plain, shard):
        # t, round, cluster, a, loss, energy, agg_count
        assert p[1:4] == s[1:4] and p[6] == s[6]
        np.testing.assert_allclose([p[0], p[4], p[5]], [s[0], s[4], s[5]],
                                   rtol=1e-5, atol=1e-6)
