"""Population engine (`repro.pop`) + multi-tenant pool serving.

The contract under test: stacking B federations along a population axis
and vmapping the fused round changes *how many* federations one device
program advances — never *what* any member computes.

* Per-member traces from `PopulationEngine.run_scanned` are bit-identical
  to standalone ``Federation.from_spec(spec).run_scanned`` runs of the
  expanded member specs, across controllers (fixed / Lyapunov / DQN),
  heterogeneous lifted scalars (lr, pkt_fail, DP sigma, fault
  intensities, the trust-vs-fedavg flag), and segmented continuation.
* `PopulationSpec` expands grids x replicates deterministically, derives
  member seeds via `member_seed` (fold_in, not ``seed + i``), and
  round-trips through dict/JSON.
* The pool supervisor (`repro.serve.pool`) drives per-member run dirs
  that speak the single-tenant file protocol: traces and checkpointed
  resume stay bit-identical to a standalone `run_service` of the same
  member spec — including resume from a ragged checkpoint frontier.
* ``pop``-labeled telemetry respects the registry's cardinality cap.
* On an 8-way forced-host mesh (subprocess) the sharded population is
  bit-identical to the unsharded one.
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

import repro.api as api
from repro.api import (AggregatorSpec, ChannelSpec, ControllerSpec,
                       Federation, FederationSpec, FleetSpec, PrivacySpec,
                       ShardingSpec, TaskSpec)
from repro.faults import FaultSpec
from repro.pop import PopulationEngine, PopulationSpec, member_seed


def _spec(seed, **kw):
    base = dict(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("mlp", {"n_samples": 256, "dim": 16, "hidden": 16}),
        execution="scanned", rounds=5, sim_seconds=1e9,
        local_batch=16, seed=seed)
    base.update(kw)
    return FederationSpec(**base)


def _tuples(trace):
    return [(r.t, r.round, r.cluster, r.a, r.loss, r.acc, r.energy,
             r.agg_count) for r in trace.records]


def _assert_member_parity(specs, traces, K):
    for b, s in enumerate(specs):
        ref = Federation.from_spec(s).run_scanned(K)
        assert _tuples(traces[b]) == _tuples(ref), f"member {b} diverged"


# --------------------------------------------------------------------- #
# spec layer
# --------------------------------------------------------------------- #
def test_member_seed_deterministic_and_distinct():
    seeds = [member_seed(7, b) for b in range(16)]
    assert seeds == [member_seed(7, b) for b in range(16)]
    assert len(set(seeds)) == 16
    assert all(isinstance(s, int) and s >= 0 for s in seeds)
    assert member_seed(8, 0) != member_seed(7, 0)


def test_population_spec_expand_grid_replicates_roundtrip():
    pspec = PopulationSpec(base=_spec(3),
                           grid={"lr": [0.1, 0.05],
                                 "channel.pkt_fail": [0.0, 0.2]},
                           replicates=2)
    assert pspec.size == 8
    members = pspec.expand()
    assert len(members) == 8
    # cartesian order, replicates innermost; derived member seeds
    assert [m.lr for m in members] == [0.1] * 4 + [0.05] * 4
    assert [m.channel.pkt_fail for m in members] == \
        ([0.0, 0.0, 0.2, 0.2] * 2)
    assert [m.seed for m in members] == \
        [member_seed(3, b) for b in range(8)]
    # dict/JSON round-trip reproduces the same expansion
    again = PopulationSpec.from_dict(
        json.loads(json.dumps(pspec.to_dict())))
    assert again.expand() == members
    # derive_seeds=False sweeps against the verbatim base seed
    fixed = pspec.replace(derive_seeds=False).expand()
    assert all(m.seed == 3 for m in fixed)


def test_population_spec_validation_errors():
    with pytest.raises(ValueError, match="replicates"):
        PopulationSpec(base=_spec(0), replicates=0).validate()
    with pytest.raises(ValueError, match="grid"):
        PopulationSpec(base=_spec(0), grid={"lr": []}).validate()
    with pytest.raises(ValueError, match="unsharded"):
        PopulationSpec(base=_spec(
            0, sharding=ShardingSpec(mesh=(2,)))).validate()
    with pytest.raises(ValueError, match="does not divide"):
        PopulationSpec(base=_spec(0), replicates=3,
                       sharding=ShardingSpec(mesh=(2,))).validate()
    with pytest.raises(KeyError, match="no field"):
        PopulationSpec(base=_spec(0), grid={"nope": [1]}).expand()


def test_population_engine_rejects_structural_mismatch():
    specs = [_spec(0), _spec(1, fleet=FleetSpec(n_devices=12))]
    with pytest.raises(ValueError, match="uniform"):
        PopulationEngine(specs)


# --------------------------------------------------------------------- #
# bit-parity with standalone runs (the tentpole invariant)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("ctl", [
    ControllerSpec("fixed", {"a": 3}),
    ControllerSpec("lyapunov", {"budget": 200.0, "horizon": 40}),
    ControllerSpec("dqn", {"episodes": 1, "horizon": 8, "seed": 0}),
], ids=["fixed", "lyapunov", "dqn"])
def test_population_trace_bit_identical(ctl):
    specs = [_spec(member_seed(11, b), controller=ctl) for b in range(2)]
    traces = PopulationEngine(specs).run_scanned(5)
    _assert_member_parity(specs, traces, 5)


def test_population_parity_heterogeneous_members():
    """Every lifted axis at once: per-member lr, pkt_fail, DP sigma,
    fault intensities + fault seed, and the trust-vs-fedavg flag (mixed
    aggregators and DP are each lifted, but cannot combine — the DP
    weight path branches on the aggregator kind)."""
    faults = lambda b: FaultSpec(                            # noqa: E731
        dropout=0.1 + 0.1 * b, straggler_frac=0.2,
        straggler_factor=2.0 + b, twin_spike_prob=0.15,
        seed=100 + b)
    mixed = [
        _spec(member_seed(19, b),
              lr=0.1 - 0.02 * b,
              channel=ChannelSpec(pkt_fail=0.05 * b),
              aggregator=AggregatorSpec(
                  "fedavg" if b == 1 else "trust"),
              faults=faults(b))
        for b in range(3)]
    _assert_member_parity(mixed, PopulationEngine(mixed).run_scanned(5), 5)

    dp = [
        _spec(member_seed(19, b),
              lr=0.1 - 0.02 * b,
              channel=ChannelSpec(pkt_fail=0.05 * b),
              privacy=PrivacySpec(clip=1.0, noise=0.01 * (b + 1)),
              faults=faults(b))
        for b in range(3)]
    _assert_member_parity(dp, PopulationEngine(dp).run_scanned(5), 5)

    forbidden = [dataclasses.replace(s, privacy=p.privacy)
                 for s, p in zip(mixed, dp)]
    with pytest.raises(ValueError, match="DP"):
        PopulationEngine(forbidden)


def test_population_segments_match_one_run():
    specs = [_spec(member_seed(23, b), lr=0.1 - 0.03 * b)
             for b in range(2)]
    pop = PopulationEngine(specs)
    first = pop.run_scanned(2, eval_final=False)
    rest = pop.run_scanned(3)
    for b, s in enumerate(specs):
        ref = Federation.from_spec(s).run_scanned(5)
        assert _tuples(first[b]) + _tuples(rest[b]) == _tuples(ref)


# --------------------------------------------------------------------- #
# pool supervisor: per-member run dirs + bit-exact ragged resume
# --------------------------------------------------------------------- #
def test_pool_serve_resume_bit_parity(tmp_path):
    from repro.serve.pool import (common_checkpoint_step, member_dir,
                                  pool_status, run_pool, write_pool_spec)
    from repro.serve.service import RunDir, run_service

    pspec = PopulationSpec(base=_spec(42), replicates=2)
    root = str(tmp_path / "pool")
    os.makedirs(root)
    write_pool_spec(root, pspec)
    quiet = lambda m: None                                   # noqa: E731

    run_pool(root, segment_rounds=2, max_segments=2, keep=None, log=quiet)
    assert common_checkpoint_step(
        [member_dir(root, b) for b in range(2)]) == 4

    # ragged frontier: member 1 lost its newest checkpoint (a crash
    # mid-sweep); resume must fall back to the common step for BOTH
    for f in os.listdir(os.path.join(member_dir(root, 1), "checkpoints")):
        if "00000004" in f:
            os.remove(os.path.join(member_dir(root, 1), "checkpoints", f))
    run_pool(root, segment_rounds=2, max_segments=2, keep=None,
             resume=True, log=quiet)

    st = pool_status(root)
    assert st["state"]["status"] == "stopped"
    assert st["state"]["rounds"] == 6
    assert [m["checkpoint_step"] for m in st["members"]] == [6, 6]

    # each member dir speaks the single-tenant protocol and its trace is
    # bit-identical to a standalone service run of the expanded spec
    for b, spec in enumerate(pspec.expand()):
        sdir = str(tmp_path / f"single{b}")
        rd = RunDir(sdir).ensure()
        rd.write_spec(spec)
        run_service(sdir, segment_rounds=2, max_segments=3, keep=None,
                    log=quiet)
        with open(os.path.join(member_dir(root, b), "trace.jsonl")) as f:
            got = [json.loads(ln) for ln in f]
        with open(rd.trace_path) as f:
            want = [json.loads(ln) for ln in f]
        assert got == want, f"member {b} trace diverged"


def test_pool_metrics_pop_label_cardinality_cap():
    from repro.obs import EngineObs
    obs = EngineObs(source="pool", max_series=4)
    g = obs.registry.gauge("pool_member_loss", "per-member loss")
    for b in range(32):
        g.set(float(b), pop=str(b))
    snap = obs.registry.snapshot()
    series = snap["families"]["pool_member_loss"]["series"]
    assert len(series) <= 5                  # cap + the overflow series
    labels = [s["labels"] for s in series]
    assert {"overflow": "true"} in labels
    dropped = snap["families"]["metrics_dropped_series_total"]["series"]
    assert dropped[0]["labels"] == {"metric": "pool_member_loss"}
    assert dropped[0]["value"] >= 28


# --------------------------------------------------------------------- #
# 8-way mesh (subprocess): sharded population parity
# --------------------------------------------------------------------- #
_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, FederationSpec,
                       FleetSpec, ShardingSpec, TaskSpec)
from repro.pop import PopulationEngine, PopulationSpec

assert jax.device_count() == 8
base = FederationSpec(
    fleet=FleetSpec(n_devices=8),
    clustering=api.ClusteringSpec(n_clusters=2),
    controller=ControllerSpec("fixed", {"a": 3}),
    aggregator=AggregatorSpec("trust"),
    task=TaskSpec("mlp", {"n_samples": 256, "dim": 16, "hidden": 16}),
    execution="scanned", rounds=4, sim_seconds=1e9,
    local_batch=16, seed=51)
rows = {}
for name, sh in (("plain", ShardingSpec()),
                 ("shard", ShardingSpec(mesh=(8,)))):
    pspec = PopulationSpec(base=base, replicates=8, sharding=sh)
    pop = PopulationEngine.from_population(pspec)
    assert (pop.mesh is not None) == (name == "shard")
    traces = pop.run_scanned(4)
    rows[name] = [[[r.t, r.round, r.cluster, r.a, r.loss, r.energy,
                    r.agg_count] for r in tr.records] for tr in traces]
print("POPPAR" + json.dumps(rows))
"""


def _run_subproc():
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.split("POPPAR", 1)[1])


def test_sharded_population_bit_identical_subprocess():
    rows = _run_subproc()
    assert len(rows["plain"]) == len(rows["shard"]) == 8
    assert rows["plain"] == rows["shard"]   # exact, every record field
