"""Distributed FL step semantics: mode A vs B equivalences, aggregation
synchronization, Eqn-19 staleness behaviour, twin calibration."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core import fl_step as fl
from repro.core.twin import calibrate, init_twins, sample_deviation
from repro.models import ArchConfig
from repro.optim import sgd

KEY = jax.random.PRNGKey(0)
CFG = ArchConfig(name="t", arch_type="dense", num_layers=2, d_model=32,
                 vocab_size=64, num_heads=2, num_kv_heads=1, d_ff=64)


def _batch_a(NC=1, C=4, n_micro=2, bm=2, seq=8):
    t = jax.random.randint(KEY, (NC, C, n_micro, bm, seq), 0, 64)
    return {"tokens": t, "labels": (t + 1) % 64}


def test_mode_a_params_synced_after_step():
    opt = sgd(0.05)
    init = core.build_init_fn(CFG, opt, mode=fl.MODE_A, n_clusters=1,
                              clients_per_cluster=4)
    state = init(KEY)
    step = jax.jit(core.build_train_step(CFG, opt, mode=fl.MODE_A))
    state, m = step(state, _batch_a(), jnp.ones((1, 4)), jnp.zeros((1,)))
    leaf = jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(np.asarray(leaf[0, 0], np.float32),
                               np.asarray(leaf[0, 3], np.float32))


def test_mode_a_trust_weights_bias_aggregate():
    """A client with all the trust should dominate the aggregate."""
    opt = sgd(0.5)
    init = core.build_init_fn(CFG, opt, mode=fl.MODE_A, n_clusters=1,
                              clients_per_cluster=2)
    state = init(KEY)
    step = jax.jit(core.build_train_step(CFG, opt, mode=fl.MODE_A))
    batch = _batch_a(C=2)
    # run two steps with different trust to see weighting effect
    rep_eq = jnp.asarray([[1.0, 1.0]])
    rep_0 = jnp.asarray([[1.0, 0.0]])
    s_eq, _ = step(state, batch, rep_eq, jnp.zeros((1,)))
    s_0, _ = step(state, batch, rep_0, jnp.zeros((1,)))
    d = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(s_eq.params), jax.tree.leaves(s_0.params)))
    assert d > 0


def test_eqn19_fresh_cluster_dominates():
    params = {"w": jnp.stack([jnp.zeros((3,)), jnp.ones((3,))])}
    fresh_first = fl.inter_cluster_agg(params, jnp.asarray([0.0, 5.0]))
    fresh_second = fl.inter_cluster_agg(params, jnp.asarray([5.0, 0.0]))
    # (e/2)^-5 / ((e/2)^0 + (e/2)^-5) ~= 0.18: fresh cluster dominates
    assert float(fresh_first["w"][0]) < 0.3       # cluster 0 (zeros) dominates
    assert float(fresh_second["w"][0]) > 0.7      # cluster 1 (ones) dominates


def test_mode_b_weighted_equals_manual_fedsgd():
    """Mode B with a_i=1: trust-weighted loss == trust-weighted FedSGD."""
    opt = sgd(0.1)
    init = core.build_init_fn(CFG, opt, mode=fl.MODE_B, n_clusters=1)
    state = init(KEY)
    step = jax.jit(core.build_train_step(CFG, opt, mode=fl.MODE_B))
    t = jax.random.randint(KEY, (1, 1, 4, 8), 0, 64)
    w = jnp.asarray([[[0.5, 0.25, 0.25, 0.0]]]) * 4.0
    batch = {"tokens": t, "labels": (t + 1) % 64, "weights": w}
    s2, _ = step(state, batch, jnp.ones((1, 1)), jnp.zeros((1,)))
    # manual: grad of weighted loss
    from repro.models import weighted_lm_loss
    p0 = jax.tree.map(lambda x: x[0], state.params)
    g = jax.grad(weighted_lm_loss)(p0, CFG,
                                   {"tokens": t[0, 0], "labels": (t[0, 0] + 1) % 64},
                                   w[0, 0], remat=True)
    manual = jax.tree.map(lambda p, gg: p - 0.1 * gg, p0, g)
    for a, b in zip(jax.tree.leaves(jax.tree.map(lambda x: x[0], s2.params)),
                    jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_client_divergence_zero_for_identical():
    params = {"w": jnp.ones((1, 4, 8))}
    d = fl.client_divergence(params)
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-6)


def test_twin_calibration_tracks_deviation():
    tw = sample_deviation(KEY, init_twins(KEY, 8), max_dev=0.2)
    for _ in range(60):
        tw = calibrate(tw, ema=0.8)
    resid = np.abs(np.asarray(tw.freq_dev - tw.dev_estimate))
    assert resid.mean() < np.abs(np.asarray(tw.freq_dev)).mean() * 0.2
