"""Deterministic fallback for ``hypothesis`` when it is not installed.

The container image does not ship hypothesis; rather than skip the property
tests entirely, this shim replays each ``@given`` test over ``max_examples``
pseudo-random samples drawn from a fixed-seed numpy generator.  It covers
exactly the strategy surface the suite uses (integers, floats, lists) and the
decorator stacking order ``@given`` above ``@settings``.

Usage in tests:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                     # container image has no hypothesis
        from _propcheck import given, settings, strategies as st
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng):
        return self._sampler(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(n)]
        return _Strategy(sample)


def settings(max_examples=10, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        max_examples = getattr(fn, "_max_examples", 10)

        # (*args) so pytest sees no named params to resolve as fixtures;
        # ``self`` arrives through *args for method-style tests.
        def wrapper(*args):
            rng = np.random.default_rng(0)
            for i in range(max_examples):
                try:
                    fn(*args, *(s.sample(rng) for s in strats))
                except AssertionError as e:
                    raise AssertionError(
                        f"falsified on example {i + 1}/{max_examples}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
