"""K-means clustering, tolerance bound (Alg. 2) and async federation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AsyncFLConfig, AsyncFederation, cluster_devices,
                        kmeans, run_sync_baseline, tolerance_bound)
from repro.core.clustering import ensure_nonempty, padded_membership
from repro.core.twin import init_twins, sample_deviation
from repro.data import dirichlet_partition, make_classification


def test_kmeans_separates_obvious_clusters():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (20, 2)) * 0.1
    b = jax.random.normal(key, (20, 2)) * 0.1 + 10.0
    assign, cent = kmeans(key, jnp.concatenate([a, b]), 2)
    assign = np.asarray(assign)
    assert len(set(assign[:20])) == 1 and len(set(assign[20:])) == 1
    assert assign[0] != assign[20]


def test_cluster_devices_groups_similar_compute():
    key = jax.random.PRNGKey(1)
    twins = sample_deviation(key, init_twins(key, 16))
    assign, _ = cluster_devices(key, twins, 4)
    assert set(np.asarray(assign)) <= set(range(4))


def test_ensure_nonempty_reseeds_empty_clusters():
    """Regression: k-means can abandon a centroid; a memberless cluster
    used to crash the engine (np.stack([]) in the per-member loop).  After
    re-seeding, every cluster owns >= 1 device and no device is lost."""
    assign = np.asarray([0, 0, 0, 0, 2, 2])        # cluster 1 and 3 empty
    fixed = ensure_nonempty(assign, 4)
    counts = np.bincount(fixed, minlength=4)
    assert (counts >= 1).all() and counts.sum() == 6
    # already-full assignments pass through untouched
    ok = np.asarray([0, 1, 2, 0, 1, 2])
    np.testing.assert_array_equal(ensure_nonempty(ok, 3), ok)


def test_engine_survives_degenerate_single_device_clusters():
    """n_devices == n_clusters forces 1-member clusters (maximal risk of
    kmeans emptying one); the engine must still build and run."""
    key = jax.random.PRNGKey(2)
    from repro.data import dirichlet_partition, make_classification
    data = make_classification(key, n=512, dim=16)
    parts = dirichlet_partition(key, data.y, 5)
    cfg = AsyncFLConfig(n_devices=5, n_clusters=5, local_batch=16,
                        sim_seconds=2.0, seed=2)
    fed = AsyncFederation(cfg, data, parts)
    assert np.bincount(fed.assign, minlength=5).min() >= 1
    tr = fed.run(eval_every=1.0)
    assert tr.times and np.isfinite(tr.losses).all()


def test_padded_partition_rejects_empty_shards():
    """A client with no data must fail loudly at init — inside the
    fixed-shape round it would silently train on dataset row 0 forever."""
    import pytest
    from repro.data import padded_partition
    with pytest.raises(ValueError, match="empty data shards"):
        padded_partition([np.arange(4), np.asarray([], np.int64)])
    idx, length = padded_partition([np.arange(4), np.arange(2)])
    assert idx.shape == (2, 4) and list(np.asarray(length)) == [4, 2]


def test_padded_membership_tables_cover_every_device_once():
    assign = np.asarray([0, 2, 2, 1, 0, 2])
    table, mask = padded_membership(assign, 3)
    table, mask = np.asarray(table), np.asarray(mask)
    assert table.shape == mask.shape == (3, 3)     # max cluster size 3
    listed = sorted(table[mask].tolist())
    assert listed == list(range(6))                # each device exactly once
    assert (table[~mask] == 6).all()               # sentinel = n


def test_tolerance_bound_caps_slow_clusters():
    a = jnp.asarray([10, 10])
    freq = jnp.asarray([2.0, 0.2])          # fast, slow
    t_min = 10 / 2.0                        # fastest cluster's round time T_m
    capped = tolerance_bound(a, freq, jnp.asarray(t_min), alpha=1.0)
    assert int(capped[0]) == 10             # fast keeps its frequency
    assert int(capped[1]) < 10              # slow is capped
    assert int(capped[1]) >= 1


def _small_fed(n_clusters, malicious=0.0, seed=0, secs=6.0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=1536, dim=48)
    parts = dirichlet_partition(key, data.y, 8)
    cfg = AsyncFLConfig(n_devices=8, n_clusters=n_clusters, local_batch=32,
                        sim_seconds=secs, malicious_frac=malicious, seed=seed)
    return AsyncFederation(cfg, data, parts), data


def test_async_federation_learns():
    fed, data = _small_fed(2)
    tr = fed.run(eval_every=1.5)
    assert tr.accs[-1] > 0.5
    assert tr.accs[-1] > tr.accs[0]


def test_trust_downweights_malicious():
    fed, _ = _small_fed(2, malicious=0.25, seed=3)
    fed.run(eval_every=2.0)
    rep = np.asarray(fed.rep)
    mal = fed.malicious
    assert rep[~mal].mean() > rep[mal].mean()


def test_more_clusters_do_more_rounds():
    """Straggler elimination: more clusters => more (async) aggregations in
    the same simulated wall-clock (Fig. 6/7 mechanism)."""
    f1, _ = _small_fed(1, seed=5)
    f4, _ = _small_fed(4, seed=5)
    t1 = f1.run(eval_every=100.0)
    t4 = f4.run(eval_every=100.0)
    assert f4.agg_count > f1.agg_count
