"""K-means clustering, tolerance bound (Alg. 2) and async federation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AsyncFLConfig, AsyncFederation, cluster_devices,
                        kmeans, run_sync_baseline, tolerance_bound)
from repro.core.twin import init_twins, sample_deviation
from repro.data import dirichlet_partition, make_classification


def test_kmeans_separates_obvious_clusters():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (20, 2)) * 0.1
    b = jax.random.normal(key, (20, 2)) * 0.1 + 10.0
    assign, cent = kmeans(key, jnp.concatenate([a, b]), 2)
    assign = np.asarray(assign)
    assert len(set(assign[:20])) == 1 and len(set(assign[20:])) == 1
    assert assign[0] != assign[20]


def test_cluster_devices_groups_similar_compute():
    key = jax.random.PRNGKey(1)
    twins = sample_deviation(key, init_twins(key, 16))
    assign, _ = cluster_devices(key, twins, 4)
    assert set(np.asarray(assign)) <= set(range(4))


def test_tolerance_bound_caps_slow_clusters():
    a = jnp.asarray([10, 10])
    freq = jnp.asarray([2.0, 0.2])          # fast, slow
    t_min = 10 / 2.0                        # fastest cluster's round time T_m
    capped = tolerance_bound(a, freq, jnp.asarray(t_min), alpha=1.0)
    assert int(capped[0]) == 10             # fast keeps its frequency
    assert int(capped[1]) < 10              # slow is capped
    assert int(capped[1]) >= 1


def _small_fed(n_clusters, malicious=0.0, seed=0, secs=6.0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=1536, dim=48)
    parts = dirichlet_partition(key, data.y, 8)
    cfg = AsyncFLConfig(n_devices=8, n_clusters=n_clusters, local_batch=32,
                        sim_seconds=secs, malicious_frac=malicious, seed=seed)
    return AsyncFederation(cfg, data, parts), data


def test_async_federation_learns():
    fed, data = _small_fed(2)
    tr = fed.run(eval_every=1.5)
    assert tr.accs[-1] > 0.5
    assert tr.accs[-1] > tr.accs[0]


def test_trust_downweights_malicious():
    fed, _ = _small_fed(2, malicious=0.25, seed=3)
    fed.run(eval_every=2.0)
    rep = np.asarray(fed.rep)
    mal = fed.malicious
    assert rep[~mal].mean() > rep[mal].mean()


def test_more_clusters_do_more_rounds():
    """Straggler elimination: more clusters => more (async) aggregations in
    the same simulated wall-clock (Fig. 6/7 mechanism)."""
    f1, _ = _small_fed(1, seed=5)
    f4, _ = _small_fed(4, seed=5)
    t1 = f1.run(eval_every=100.0)
    t4 = f4.run(eval_every=100.0)
    assert f4.agg_count > f1.agg_count
