"""Data pipeline (non-IID partitioner) and checkpoint round-trip tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.data import (dirichlet_partition, federated_batches, lm_batches,
                        make_classification, token_stream)


def test_partition_covers_all_indices_once():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=2000, dim=8)
    parts = dirichlet_partition(key, data.y, 8)
    allidx = np.concatenate(parts)
    assert len(allidx) == 2000
    assert len(set(allidx.tolist())) == 2000


@given(st.floats(0.05, 5.0), st.integers(2, 10))
@settings(max_examples=10, deadline=None)
def test_partition_skew_property(alpha, n_clients):
    key = jax.random.PRNGKey(int(alpha * 100) + n_clients)
    data = make_classification(key, n=1000, dim=4)
    parts = dirichlet_partition(key, data.y, n_clients, alpha=alpha)
    assert sum(len(p) for p in parts) == 1000


def test_low_alpha_is_more_skewed_than_high():
    key = jax.random.PRNGKey(3)
    data = make_classification(key, n=4000, dim=4)
    y = np.asarray(data.y)

    def skew(alpha):
        parts = dirichlet_partition(jax.random.PRNGKey(7), y, 8, alpha=alpha)
        fracs = []
        for p in parts:
            if len(p) == 0:
                continue
            c = np.bincount(y[p], minlength=10) / len(p)
            fracs.append(c.max())
        return np.mean(fracs)

    assert skew(0.1) > skew(100.0)


def test_federated_batches_shapes():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=512, dim=8)
    parts = dirichlet_partition(key, data.y, 4)
    x, y = federated_batches(key, data.x, data.y, parts, batch=16)
    assert x.shape == (4, 16, 8) and y.shape == (4, 16)


def test_token_stream_zipf():
    toks = np.asarray(token_stream(jax.random.PRNGKey(0), 20000, 1000))
    counts = np.bincount(toks, minlength=1000)
    assert counts[:10].sum() > counts[500:510].sum()   # head-heavy


def test_lm_batches_next_token():
    b = next(iter(lm_batches(jax.random.PRNGKey(0), 64, 2, 8, 1)))
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)],
            "c": {"d": jnp.asarray(2.5)}}
    with tempfile.TemporaryDirectory() as d:
        f = save_checkpoint(d, 42, tree)
        assert latest_checkpoint(d) == f
        got = load_checkpoint(f, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))


def test_checkpoint_typed_prng_key_continues_the_stream():
    """A typed PRNG-key leaf round-trips through the ``__key__:`` marker
    and the restored key draws the exact same stream."""
    key = jax.random.fold_in(jax.random.key(7), 3)
    tree = {"key": key, "w": jnp.ones((2,), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        got = load_checkpoint(save_checkpoint(d, 0, tree), tree)
    restored = got["key"]
    assert jnp.issubdtype(restored.dtype, jax.dtypes.prng_key)
    assert str(jax.random.key_impl(restored)) \
        == str(jax.random.key_impl(key))
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(restored)),
        np.asarray(jax.random.key_data(key)))
    np.testing.assert_array_equal(
        np.asarray(jax.random.normal(restored, (8,))),
        np.asarray(jax.random.normal(key, (8,))))


@given(st.integers(0, 2**31 - 1), st.integers(1, 8), st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_checkpoint_fleetstate_like_tree_property(seed, n, m):
    """FleetState-shaped trees — mixed f32/bf16/int/scalar leaves plus a
    typed key — round-trip with dtypes and bits intact."""
    k = jax.random.PRNGKey(seed)
    tree = {"params": {"w": jax.random.normal(k, (n, m)),
                       "h": jax.random.normal(k, (m,)).astype(jnp.bfloat16)},
            "queue": jnp.asarray(float(n) * 1.5, jnp.float32),
            "round": jnp.asarray(seed % 97, jnp.int32),
            "key": jax.random.fold_in(jax.random.key(seed), n)}
    with tempfile.TemporaryDirectory() as d:
        got = load_checkpoint(save_checkpoint(d, seed % 100, tree), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        if jnp.issubdtype(a.dtype, jax.dtypes.prng_key):
            a, b = jax.random.key_data(a), jax.random.key_data(b)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_write_is_atomic():
    """No ``.tmp`` survivor after a save, and an orphaned ``.tmp`` from a
    crashed writer is invisible to `latest_checkpoint`."""
    tree = {"w": jnp.ones((3,), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        f = save_checkpoint(d, 1, tree)
        assert os.listdir(d) == [os.path.basename(f)]
        with open(os.path.join(d, "ckpt_00000009.npz.tmp"), "wb") as fh:
            fh.write(b"torn half-written archive")
        assert latest_checkpoint(d) == f
