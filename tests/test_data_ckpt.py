"""Data pipeline (non-IID partitioner) and checkpoint round-trip tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.data import (dirichlet_partition, federated_batches, lm_batches,
                        make_classification, token_stream)


def test_partition_covers_all_indices_once():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=2000, dim=8)
    parts = dirichlet_partition(key, data.y, 8)
    allidx = np.concatenate(parts)
    assert len(allidx) == 2000
    assert len(set(allidx.tolist())) == 2000


@given(st.floats(0.05, 5.0), st.integers(2, 10))
@settings(max_examples=10, deadline=None)
def test_partition_skew_property(alpha, n_clients):
    key = jax.random.PRNGKey(int(alpha * 100) + n_clients)
    data = make_classification(key, n=1000, dim=4)
    parts = dirichlet_partition(key, data.y, n_clients, alpha=alpha)
    assert sum(len(p) for p in parts) == 1000


def test_low_alpha_is_more_skewed_than_high():
    key = jax.random.PRNGKey(3)
    data = make_classification(key, n=4000, dim=4)
    y = np.asarray(data.y)

    def skew(alpha):
        parts = dirichlet_partition(jax.random.PRNGKey(7), y, 8, alpha=alpha)
        fracs = []
        for p in parts:
            if len(p) == 0:
                continue
            c = np.bincount(y[p], minlength=10) / len(p)
            fracs.append(c.max())
        return np.mean(fracs)

    assert skew(0.1) > skew(100.0)


def test_federated_batches_shapes():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, n=512, dim=8)
    parts = dirichlet_partition(key, data.y, 4)
    x, y = federated_batches(key, data.x, data.y, parts, batch=16)
    assert x.shape == (4, 16, 8) and y.shape == (4, 16)


def test_token_stream_zipf():
    toks = np.asarray(token_stream(jax.random.PRNGKey(0), 20000, 1000))
    counts = np.bincount(toks, minlength=1000)
    assert counts[:10].sum() > counts[500:510].sum()   # head-heavy


def test_lm_batches_next_token():
    b = next(iter(lm_batches(jax.random.PRNGKey(0), 64, 2, 8, 1)))
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)],
            "c": {"d": jnp.asarray(2.5)}}
    with tempfile.TemporaryDirectory() as d:
        f = save_checkpoint(d, 42, tree)
        assert latest_checkpoint(d) == f
        got = load_checkpoint(f, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
