"""repro.obs: metrics registry, spans, JSONL hardening, bit-parity.

The load-bearing guarantee is **trace bit-parity**: attaching an
`EngineObs` must not change the compiled round program, so an
instrumented run's trace equals an uninstrumented run's record for
record — on both the event-loop and the scanned path.  Everything else
here pins the registry semantics (cardinality guard, Prometheus text
golden, snapshot round-trip), the span tree machinery, and the JSONL
crash hardening (torn final lines, sink reopen after rotation).
"""
import dataclasses
import json
import os

import jax
import pytest

import repro.api as api
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec, TaskSpec)
from repro.api.records import (JsonlSink, RoundRecord, read_jsonl_trace,
                               tail_jsonl)
from repro.data import dirichlet_partition, make_classification
from repro.obs import (METRICS_SCHEMA, SPAN_SCHEMA, EngineObs,
                       MetricsRegistry, SpanRecorder,
                       merge_snapshot_records, snapshot_record)


def _data(n=1536, dim=48, devices=8, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    return data, dirichlet_partition(key, data.y, devices)


def _spec(seed=0, execution="scanned"):
    return FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        execution=execution, rounds=4, sim_seconds=1e9,
        local_batch=32, seed=seed)


class ListSink:
    def __init__(self):
        self.records = []

    def append(self, rec):
        self.records.append(rec)


# --------------------------------------------------------------------- #
# registry semantics
# --------------------------------------------------------------------- #
def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("rounds_total", "rounds")
    c.inc()
    c.inc(2, cluster="0")
    c.inc(3, cluster="1")
    assert c.value() == 1
    assert c.value(cluster="0") == 2
    assert c.total() == 6
    with pytest.raises(ValueError):
        c.inc(-1)

    g = reg.gauge("queue", "deficit")
    g.set(4.5)
    g.set(2.0)
    assert g.value() == 2.0

    h = reg.histogram("dur", "round duration", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    s = h._series[()]
    assert s.counts == [1, 1, 1]        # <=0.1, <=1.0, +Inf
    assert s.count == 3
    assert s.sum == pytest.approx(5.55)

    # re-declaration is idempotent per kind, an error across kinds
    assert reg.counter("rounds_total") is c
    with pytest.raises(ValueError):
        reg.gauge("rounds_total")
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 0.5))


def test_cardinality_guard_collapses_to_overflow():
    reg = MetricsRegistry(max_series=2)
    c = reg.counter("per_device", "per-device tally")
    for i in range(5):
        c.inc(1, device=str(i))
    # 2 real series + the reserved overflow series holding the rest
    assert c.value(device="0") == 1 and c.value(device="1") == 1
    assert c.value(overflow="true") == 3
    assert c.total() == 5
    dropped = reg.get("metrics_dropped_series_total")
    assert dropped.value(metric="per_device") == 3


def test_prometheus_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("fl_rounds_total", "rounds executed").inc(7)
    g = reg.gauge("fl_loss", "last loss")
    g.set(0.25)
    c2 = reg.counter("fl_cluster_rounds_total", "per cluster")
    c2.inc(4, cluster="0")
    c2.inc(3, cluster="1")
    h = reg.histogram("fl_dur", "duration", buckets=(0.5, 1.0))
    h.observe(0.3)
    h.observe(2.0)
    assert reg.to_prometheus() == (
        "# HELP fl_cluster_rounds_total per cluster\n"
        "# TYPE fl_cluster_rounds_total counter\n"
        'fl_cluster_rounds_total{cluster="0"} 4\n'
        'fl_cluster_rounds_total{cluster="1"} 3\n'
        "# HELP fl_dur duration\n"
        "# TYPE fl_dur histogram\n"
        'fl_dur_bucket{le="0.5"} 1\n'
        'fl_dur_bucket{le="1"} 1\n'
        'fl_dur_bucket{le="+Inf"} 2\n'
        "fl_dur_sum 2.3\n"
        "fl_dur_count 2\n"
        "# HELP fl_loss last loss\n"
        "# TYPE fl_loss gauge\n"
        "fl_loss 0.25\n"
        "# HELP fl_rounds_total rounds executed\n"
        "# TYPE fl_rounds_total counter\n"
        "fl_rounds_total 7\n")


def test_snapshot_roundtrip_is_lossless():
    reg = MetricsRegistry()
    reg.counter("a_total", "a").inc(3, k="v")
    reg.gauge("b", "b").set(-1.5)
    reg.histogram("c", "c", buckets=(1.0,)).observe(0.5)
    snap = json.loads(json.dumps(reg.snapshot()))   # through JSON
    assert snap["schema"] == METRICS_SCHEMA
    back = MetricsRegistry.from_snapshot(snap)
    assert back.totals() == reg.totals()
    assert back.to_prometheus() == reg.to_prometheus()
    with pytest.raises(ValueError):
        MetricsRegistry.from_snapshot({"schema": "metrics/999"})


def test_merge_snapshot_records_latest_per_source():
    service, chaos = MetricsRegistry(), MetricsRegistry()
    c = service.counter("fl_rounds_total", "rounds")
    k = chaos.counter("chaos_sigkills_total", "kills")
    c.inc(5)
    old = snapshot_record(service, source="service", ts=1.0)
    c.inc(5)
    new = snapshot_record(service, source="service", ts=2.0)
    k.inc(1)
    ch = snapshot_record(chaos, source="chaos", ts=1.5)
    merged = merge_snapshot_records([old, ch, new])
    got = MetricsRegistry.from_snapshot(merged).totals()
    assert got["fl_rounds_total"] == 10          # latest service snapshot
    assert got["chaos_sigkills_total"] == 1      # merged across sources
    assert merge_snapshot_records([{"schema": "span/1"}]) is None


# --------------------------------------------------------------------- #
# spans
# --------------------------------------------------------------------- #
def test_span_nesting_and_sink_emission():
    sink = ListSink()
    rec = SpanRecorder(sink=sink)
    with rec.span("segment", segment=1) as seg:
        with rec.span("round", rounds=4) as rd:
            rd.mark("dispatch")
        with rec.span("checkpoint"):
            pass
    assert [c.name for c in seg.children] == ["round", "checkpoint"]
    assert "dispatch_s" in seg.children[0].attrs
    assert seg.dur_s >= sum(c.dur_s for c in seg.children) > 0
    # only the completed root is emitted; children nest inside it
    assert len(sink.records) == 1
    root = sink.records[0]
    assert root["schema"] == SPAN_SCHEMA
    assert root["name"] == "segment"
    assert [c["name"] for c in root["children"]] == ["round", "checkpoint"]
    assert rec.last("segment") is seg
    assert rec.last("nope") is None


def test_span_fence_blocks_on_device_values():
    rec = SpanRecorder()
    x = None
    with rec.span("round", fence_on=None) as sp:
        x = jax.numpy.ones((8, 8)) @ jax.numpy.ones((8, 8))
        sp.mark("dispatch")
    # fencing on the result must be tolerated for arbitrary pytrees too
    with rec.span("fenced", fence_on={"x": x, "n": 3}):
        pass
    assert rec.last("fenced").dur_s >= 0


# --------------------------------------------------------------------- #
# JSONL hardening (satellites: torn lines, sink reopen)
# --------------------------------------------------------------------- #
def _write_trace(path, n):
    recs = [RoundRecord(t=float(i), round=i + 1, cluster=0, a=2,
                        loss=1.0 / (i + 1), acc=None, energy=float(i),
                        agg_count=i) for i in range(n)]
    with open(path, "w") as f:
        for r in recs:
            f.write(json.dumps(dataclasses.asdict(r)) + "\n")
    return recs


def test_read_jsonl_trace_skips_torn_final_line(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    recs = _write_trace(path, 3)
    with open(path, "a") as f:            # writer SIGKILLed mid-append
        f.write('{"t": 3.0, "round": 4, "clu')
    trace = read_jsonl_trace(path)
    assert trace.records == recs
    assert tail_jsonl(path, n=10) == [dataclasses.asdict(r) for r in recs]


def test_read_jsonl_trace_rejects_mid_file_corruption(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    recs = _write_trace(path, 3)
    lines = open(path).read().splitlines()
    lines[1] = lines[1][:20]              # torn line with records after it
    open(path, "w").write("\n".join(lines) + "\n")
    with pytest.raises(json.JSONDecodeError):
        read_jsonl_trace(path)
    del recs


def test_jsonl_sink_reopens_after_rotation(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    sink = JsonlSink(path)
    sink.append({"i": 0})
    os.replace(path, path + ".1")         # logrotate-style move-away
    sink.append({"i": 1})                 # must land in a fresh file
    os.unlink(path)                       # hostile: unlink underneath
    sink.append({"i": 2})
    sink.close()
    assert [r["i"] for r in tail_jsonl(path, n=10)] == [2]
    assert [r["i"] for r in tail_jsonl(path + ".1", n=10)] == [0]
    # dataclass records still serialize (the trace.jsonl path)
    sink2 = JsonlSink(path)
    sink2.append(RoundRecord(t=0.0, round=1, cluster=0, a=1, loss=1.0,
                             acc=None, energy=0.0, agg_count=0))
    sink2.close()
    assert tail_jsonl(path, n=1)[0]["round"] == 1


# --------------------------------------------------------------------- #
# bit-parity: telemetry must not perturb the trace
# --------------------------------------------------------------------- #
def test_scanned_trace_bit_parity_with_obs(tmp_path):
    data, parts = _data(seed=5)
    plain = Federation.from_spec(_spec(seed=5), data=data, parts=parts)
    want = plain.engine.run_scanned(6, eval_final=False).records

    sink = JsonlSink(str(tmp_path / "metrics.jsonl"))
    obs = EngineObs(sink=sink, source="service")
    inst = Federation.from_spec(_spec(seed=5), data=data, parts=parts)
    inst.engine.set_obs(obs)
    got = inst.engine.run_scanned(6, eval_final=False).records

    assert len(got) == len(want) == 6
    for a, b in zip(want, got):
        assert a == b                     # dataclass eq: floats exact
    totals = obs.registry.totals()
    assert totals["fl_rounds_total"] == 6
    assert totals["fl_compiles_total"] == 1
    assert totals["fl_sim_seconds_total"] > 0
    assert obs.spans.last("round") is not None
    assert obs.spans.last("compile") is not None
    sink.close()
    schemas = [r.get("schema")
               for r in tail_jsonl(str(tmp_path / "metrics.jsonl"), n=64)]
    assert SPAN_SCHEMA in schemas and "event/1" in schemas


def test_event_loop_trace_bit_parity_with_obs():
    data, parts = _data(seed=6)
    plain = Federation.from_spec(_spec(seed=6, execution="event"),
                                 data=data, parts=parts)
    want = plain.run(eval_every=1.0, max_rounds=10).records

    obs = EngineObs()
    inst = Federation.from_spec(_spec(seed=6, execution="event"),
                                data=data, parts=parts)
    inst.engine.set_obs(obs)
    got = inst.run(eval_every=1.0, max_rounds=10).records

    assert len(got) == len(want) > 0
    for a, b in zip(want, got):
        assert a == b
    totals = obs.registry.totals()
    assert totals["fl_rounds_total"] == 10
    assert totals["fl_evals_total"] > 0
    assert totals["fl_energy_joules_total"] > 0


def test_state_summary_is_read_only():
    data, parts = _data(seed=7)
    fed = Federation.from_spec(_spec(seed=7), data=data, parts=parts)
    fed.engine.run_scanned(3, eval_final=False)
    before = jax.tree.map(lambda x: x, fed.engine.state)
    summary = fed.engine.obs_state_summary()
    for k in ("queue_deficit", "reputation_min", "reputation_mean",
              "reputation_max", "twin_beta_sum"):
        assert isinstance(summary[k], float)
    assert summary["reputation_min"] <= summary["reputation_mean"] \
        <= summary["reputation_max"]
    after = fed.engine.run_scanned(3, eval_final=False)
    del before, after                     # summary ran between segments
    # and calling it again mid-stream gives the same numbers (pure read)
    assert fed.engine.obs_state_summary() == fed.engine.obs_state_summary()


# --------------------------------------------------------------------- #
# serve integration: metrics.jsonl + status metrics block
# --------------------------------------------------------------------- #
def _tiny_spec_file(tmp_path):
    spec = FederationSpec(
        fleet=FleetSpec(n_devices=8),
        clustering=api.ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 2}),
        aggregator=AggregatorSpec("trust"),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 512, "dim": 16, "n_types": 4,
                       "latent": 2, "hidden": 16, "code": 4,
                       "dirichlet_alpha": 5.0}),
        execution="scanned", rounds=3, sim_seconds=1e9,
        local_batch=16, lr=0.1, seed=11)
    path = tmp_path / "spec.json"
    path.write_text(json.dumps(spec.to_dict()))
    return str(path)


def test_serve_metrics_file_and_status_block(tmp_path):
    from repro.serve.__main__ import main
    from repro.serve.service import load_run_metrics, service_status

    run_dir = str(tmp_path / "run")
    assert main(["start", "--run-dir", run_dir,
                 "--spec-file", _tiny_spec_file(tmp_path),
                 "--segment-rounds", "3", "--max-segments", "2",
                 "--foreground"]) == 0

    recs = tail_jsonl(os.path.join(run_dir, "metrics.jsonl"), n=64)
    schemas = {r.get("schema") for r in recs}
    assert {METRICS_SCHEMA, SPAN_SCHEMA, "event/1"} <= schemas
    seg = [r for r in recs if r.get("schema") == SPAN_SCHEMA
           and r.get("name") == "segment"]
    assert len(seg) == 2
    assert {c["name"] for c in seg[-1]["children"]} \
        >= {"round", "checkpoint"}

    st = service_status(run_dir)
    m = st["metrics"]
    assert m["fl_rounds_total"] == 6
    assert m["fl_checkpoints_total"] == 2
    assert m["service_segments_total"] == 2
    assert st["last_span"]["name"] == "segment"

    # the Prometheus dump path works off the same merged snapshot
    text = MetricsRegistry.from_snapshot(
        load_run_metrics(run_dir)).to_prometheus()
    assert "fl_rounds_total 6" in text
    assert 'fl_compiles_total{fn="' in text

    assert main(["metrics", "--run-dir", run_dir]) == 0
    assert main(["status", "--run-dir", run_dir, "--watch", "--once"]) == 0
