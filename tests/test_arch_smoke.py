"""Per-assigned-architecture smoke tests: reduced same-family variants run
one forward + one train step + one decode step on CPU, asserting output
shapes and no NaNs (full configs are exercised by launch/dryrun.py only)."""
import jax
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.configs import all_arch_ids, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params
from repro.optim import adam

KEY = jax.random.PRNGKey(0)


def _tokens(cfg, batch, seq):
    if cfg.num_codebooks > 1:
        return jax.random.randint(KEY, (batch, cfg.num_codebooks, seq),
                                  0, cfg.vocab_size)
    return jax.random.randint(KEY, (batch, seq), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_forward_and_shapes(arch):
    cfg = get_smoke_config(arch)
    p = init_params(KEY, cfg)
    toks = _tokens(cfg, 2, 16)
    logits, aux = forward(p, cfg, toks, remat=False)
    if cfg.num_codebooks > 1:
        assert logits.shape == (2, cfg.num_codebooks, 16, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = adam(1e-3)
    mode = cfg.fl_mode
    init = core.build_init_fn(cfg, opt, mode=mode, n_clusters=1,
                              clients_per_cluster=2)
    state = init(KEY)
    step = jax.jit(core.build_train_step(cfg, opt, mode=mode))
    seq = 16
    if mode == core.MODE_A:
        toks = jax.tree.map(
            lambda _: None, None) or _tokens(cfg, 2, seq)[None, :, None]
        # (NC=1, C=2, n_micro=1, Bm, ...)
        t = _tokens(cfg, 2 * 2, seq).reshape(
            (1, 2, 1, 2) + _tokens(cfg, 1, seq).shape[1:])
        batch = {"tokens": t, "labels": (t + 1) % cfg.vocab_size}
        rep = jnp.ones((1, 2))
    else:
        t = _tokens(cfg, 4, seq).reshape(
            (1, 1, 4) + _tokens(cfg, 1, seq).shape[1:])
        batch = {"tokens": t, "labels": (t + 1) % cfg.vocab_size,
                 "weights": jnp.ones((1, 1, 4))}
        rep = jnp.ones((1, 1))
    stale = jnp.zeros((1,))
    state2, metrics = step(state, batch, rep, stale)
    loss = float(jnp.mean(metrics["loss"]))
    assert loss == loss and loss > 0        # finite, positive
    # params actually changed
    l0 = jax.tree.leaves(state.params)[1]
    l1 = jax.tree.leaves(state2.params)[1]
    assert float(jnp.max(jnp.abs(l1.astype(jnp.float32) -
                                 l0.astype(jnp.float32)))) > 0


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    p = init_params(KEY, cfg)
    cache = init_cache(cfg, 2, 32)
    tok = (_tokens(cfg, 2, 1)[..., 0])
    logits, cache = decode_step(p, cache, cfg, tok, jnp.int32(0))
    if cfg.num_codebooks > 1:
        assert logits.shape == (2, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
