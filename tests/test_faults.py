"""Fault-injection layer: spec round-trip, in-jit fault semantics on both
execution paths, graceful degradation under total dropout, and the trust
pipeline actually penalizing the injected Byzantine subsets."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.api import (AggregatorSpec, ClusteringSpec, ControllerSpec,
                       Federation, FederationSpec, FleetSpec, TaskSpec)
from repro.core.clustering import ensure_nonempty
from repro.faults import CORRUPT_MODES, FaultModel, FaultSpec


def _spec(faults=None, **kw):
    base = dict(
        fleet=FleetSpec(n_devices=8),
        clustering=ClusteringSpec(n_clusters=2),
        controller=ControllerSpec("fixed", {"a": 3}),
        aggregator=AggregatorSpec("trust"),
        execution="scanned", rounds=6, sim_seconds=1e9, local_batch=16,
        seed=3)
    base.update(kw)
    spec = FederationSpec(**base)
    if faults is not None:
        spec = dataclasses.replace(spec, faults=faults)
    return spec


# --------------------------------------------------------------------- #
# spec: dict round-trip + validation
# --------------------------------------------------------------------- #
def test_fault_spec_roundtrip():
    fs = FaultSpec(dropout=0.2, straggler_frac=0.1, twin_spike_prob=0.05,
                   corrupt_mode="gaussian", corrupt_frac=0.25,
                   poison_frac=0.125, seed=4)
    spec = _spec(faults=fs)
    back = FederationSpec.from_dict(spec.to_dict())
    assert back.faults == fs
    assert back == spec


def test_default_fault_spec_is_inert():
    fs = FederationSpec().faults
    assert fs == FaultSpec()
    assert not fs.active
    m = FaultModel(fs, 8)
    assert not (m.may_drop or m.may_straggle or m.may_spike
                or m.may_corrupt or m.may_poison)


@pytest.mark.parametrize("bad", [
    {"corrupt_mode": "bitflip"},
    {"dropout": 1.5},
    {"straggler_frac": -0.1},
    {"corrupt_scale": -1.0, "corrupt_mode": "gaussian",
     "corrupt_frac": 0.5},
])
def test_fault_spec_validation_rejects(bad):
    with pytest.raises(ValueError):
        FaultSpec(**bad).validate()


def test_datacenter_scale_rejects_active_faults():
    spec = FederationSpec.from_dict(_spec().to_dict())  # device scale ok
    spec = dataclasses.replace(
        spec, scale="datacenter",
        task=TaskSpec("lm", {"seq": 8, "micro_batch": 1}),
        faults=FaultSpec(dropout=0.5))
    with pytest.raises(ValueError, match="faults"):
        spec.validate()


def test_corrupt_modes_exported():
    assert "sign_flip" in CORRUPT_MODES and "none" in CORRUPT_MODES


# --------------------------------------------------------------------- #
# engine semantics
# --------------------------------------------------------------------- #
def test_event_and_scanned_paths_agree_under_faults():
    """The fault program is part of the fused round, so the event-heap and
    lax.scan lowerings of a faulty federation stay in lockstep."""
    fs = FaultSpec(dropout=0.25, straggler_frac=0.25, twin_spike_prob=0.2,
                   corrupt_mode="sign_flip", corrupt_frac=0.25,
                   poison_frac=0.25, seed=2)
    ev = Federation.from_spec(_spec(faults=fs)).run()
    sc = Federation.from_spec(_spec(faults=fs)).run_scanned(6)
    assert [r.a for r in ev.records[:6]] == [r.a for r in sc.records[:6]]
    np.testing.assert_allclose(
        [r.loss for r in ev.records[:6]],
        [r.loss for r in sc.records[:6]], rtol=1e-6)
    np.testing.assert_allclose(
        [r.energy for r in ev.records[:6]],
        [r.energy for r in sc.records[:6]], rtol=1e-6)


def test_total_dropout_carries_state_gracefully():
    """dropout=1.0 drops every member of every round: the engine must skip
    the events (params, twins, reputation unchanged; zero energy) instead
    of writing the degenerate all-padding aggregate."""
    fed = Federation.from_spec(_spec(faults=FaultSpec(dropout=1.0)))
    g0 = jax.tree.map(jnp.copy, fed.engine.global_params)
    rep0 = jnp.copy(fed.engine.rep)
    tr = fed.run_scanned(6)
    assert all(np.isfinite(r.loss) for r in tr.records)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 g0, fed.engine.global_params)
    np.testing.assert_array_equal(rep0, fed.engine.rep)
    assert float(fed.engine.energy_used) == 0.0
    # the scheduler still advances: rounds were consumed, not deadlocked
    assert int(fed.engine.round) == 6


def test_partial_dropout_still_trains():
    tr = Federation.from_spec(
        _spec(faults=FaultSpec(dropout=0.3, seed=1))).run_scanned(6)
    assert all(np.isfinite(r.loss) for r in tr.records)
    assert tr.records[-1].energy > 0.0


def test_straggler_inflates_round_duration():
    slow = Federation.from_spec(_spec(faults=FaultSpec(
        straggler_frac=1.0, straggler_factor=8.0))).run_scanned(6)
    fast = Federation.from_spec(_spec()).run_scanned(6)
    # identical rounds, identical controller — only the wall-clock of each
    # event is stretched by the straggler factor
    assert slow.times[-1] > 4.0 * fast.times[-1]


def test_corrupt_devices_lose_reputation():
    fs = FaultSpec(corrupt_mode="sign_flip", corrupt_frac=0.25,
                   corrupt_scale=4.0, seed=5)
    fed = Federation.from_spec(_spec(faults=fs, rounds=12))
    bad = np.asarray(fed.engine.faults.corrupt_dev) > 0.5
    assert bad.sum() == 2               # 0.25 * 8 devices
    fed.run_scanned(12)
    rep = np.asarray(fed.engine.rep)
    assert rep[bad].mean() < rep[~bad].mean()


def test_poisoned_devices_lose_reputation():
    fs = FaultSpec(poison_frac=0.25, poison_scale=8.0, seed=5)
    fed = Federation.from_spec(_spec(faults=fs, rounds=12))
    bad = np.asarray(fed.engine.faults.poison_dev) > 0.5
    assert bad.sum() == 2
    fed.run_scanned(12)
    rep = np.asarray(fed.engine.rep)
    assert rep[bad].mean() < rep[~bad].mean()


def test_poison_is_deterministic_per_device():
    """The poison bias is frozen per device: two engines built from the
    same spec inject identical patterns (resume-safety for serve)."""
    fs = FaultSpec(poison_frac=0.5, poison_scale=2.0, seed=7)
    a = Federation.from_spec(_spec(faults=fs)).run_scanned(6)
    b = Federation.from_spec(_spec(faults=fs)).run_scanned(6)
    assert [r.loss for r in a.records] == [r.loss for r in b.records]


def test_fault_seed_changes_realization():
    f1 = Federation.from_spec(
        _spec(faults=FaultSpec(dropout=0.5, seed=1))).run_scanned(6)
    f2 = Federation.from_spec(
        _spec(faults=FaultSpec(dropout=0.5, seed=2))).run_scanned(6)
    assert [r.loss for r in f1.records] != [r.loss for r in f2.records]


def test_autoencoder_poisoning_runs_in_jit():
    """Input poisoning on the reconstruction task (corrupt_labels no-op):
    the acceptance workload for the robustness bench."""
    spec = _spec(
        faults=FaultSpec(poison_frac=0.375, poison_scale=4.0),
        task=TaskSpec("autoencoder-anomaly",
                      {"n_samples": 512, "dim": 16, "n_types": 4,
                       "hidden": 32, "code": 4}),
        local_batch=16, lr=0.1)
    tr = Federation.from_spec(spec).run_scanned(6)
    assert all(np.isfinite(r.loss) for r in tr.records)


# --------------------------------------------------------------------- #
# graceful-degradation property: ensure_nonempty edge cases
# --------------------------------------------------------------------- #
class TestEnsureNonemptyProperty:
    @given(st.integers(2, 24), st.integers(1, 8), st.integers(0, 10 ** 6))
    @settings(max_examples=25, deadline=None)
    def test_every_cluster_nonempty(self, n, k, seed):
        k = min(k, n)
        rng = np.random.default_rng(seed)
        assign = rng.integers(0, k, size=n)
        fixed = ensure_nonempty(assign, k)
        counts = np.bincount(fixed, minlength=k)
        assert (counts >= 1).all()
        assert fixed.shape == (n,)
        assert ((fixed >= 0) & (fixed < k)).all()

    def test_rejects_more_clusters_than_devices(self):
        with pytest.raises(ValueError):
            ensure_nonempty(np.zeros(3, np.int64), 4)

    @given(st.integers(2, 16), st.integers(0, 10 ** 6))
    @settings(max_examples=10, deadline=None)
    def test_degenerate_single_cluster_assignment(self, k, seed):
        """All devices piled on one cluster — the k-means failure mode the
        dropout fault can mimic at runtime — redistributes to k nonempty."""
        n = k + int(np.random.default_rng(seed).integers(0, 8))
        assign = np.zeros(n, np.int64)
        fixed = ensure_nonempty(assign, k)
        assert (np.bincount(fixed, minlength=k) >= 1).all()
