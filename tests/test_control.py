"""In-jit control plane (`repro.control`): replay ring-buffer properties,
scanned-vs-eager Alg.-1 training parity, masked median, distilled table
policy, and `run_scanned(K)` trace parity with the event-heap engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

import repro.api as api
import repro.control as ctl
from repro.api import (AggregatorSpec, ControllerSpec, Federation,
                       FederationSpec, FleetSpec)
from repro.core import dqn as dqn_lib
from repro.core import envs
from repro.data import dirichlet_partition, make_classification


def _data(n=1536, dim=48, devices=8, seed=0):
    key = jax.random.PRNGKey(seed)
    data = make_classification(key, n=n, dim=dim)
    return data, dirichlet_partition(key, data.y, devices)


def _spec(seed, controller, n_clusters=3, **kw):
    kw.setdefault("fleet", FleetSpec(n_devices=8))
    return FederationSpec(
        clustering=api.ClusteringSpec(n_clusters=n_clusters),
        controller=controller,
        sim_seconds=1e9, local_batch=32, seed=seed, **kw)


# --------------------------------------------------------------------- #
# replay ring buffer (the scan's experience store)
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=40))
def test_replay_ring_buffer_wraparound(cap, pushes):
    """After n pushes into a capacity-cap ring, slot i holds the latest
    value written to it (push k lands at k % cap), ptr == n % cap, and
    full <=> n >= cap."""
    cfg = dqn_lib.DQNConfig(buffer_size=cap, state_dim=2, n_actions=2)
    state = dqn_lib.init_dqn(jax.random.PRNGKey(0), cfg)
    for k in range(pushes):
        state = dqn_lib.store(state, jnp.full((2,), k, jnp.float32),
                              jnp.int32(k % 2), jnp.float32(k),
                              jnp.zeros(2))
    rep = state.replay
    assert int(rep.ptr) == pushes % cap
    assert bool(rep.full) == (pushes >= cap)
    r = np.asarray(rep.r)
    for i in range(cap):
        wrote = [k for k in range(pushes) if k % cap == i]
        expect = float(wrote[-1]) if wrote else 0.0
        assert r[i] == expect, f"slot {i}: {r[i]} != {expect}"


# --------------------------------------------------------------------- #
# scanned Alg.-1 training == the same step function run eagerly
# --------------------------------------------------------------------- #
def test_scanned_dqn_matches_eager():
    cfg = dqn_lib.DQNConfig(buffer_size=96, batch_size=16, lr=2e-3)
    p = envs.EnvParams(horizon=10, p_good=0.5)
    agent0 = dqn_lib.init_dqn(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    scanned, aux_s = ctl.train_on_env(key, agent0, cfg, p, episodes=2,
                                      scan=True)
    eager, aux_e = ctl.train_on_env(key, agent0, cfg, p, episodes=2,
                                    scan=False)
    assert int(scanned.step) == int(eager.step) == 20
    np.testing.assert_array_equal(np.asarray(scanned.replay.a),
                                  np.asarray(eager.replay.a))
    np.testing.assert_array_equal(np.asarray(aux_s["ep_len"]),
                                  np.asarray(aux_e["ep_len"]))
    np.testing.assert_allclose(np.asarray(aux_s["ep_return"]),
                               np.asarray(aux_e["ep_return"]),
                               rtol=1e-6, atol=1e-7)
    for k in scanned.eval_params:
        np.testing.assert_allclose(
            np.asarray(scanned.eval_params[k]),
            np.asarray(eager.eval_params[k]), rtol=2e-6, atol=1e-7,
            err_msg=f"eval_params[{k}] diverged between scan and eager")


def test_early_termination_freezes_episode():
    """A budget so tight the episode ends on step 1: the trailing scan steps
    must not keep writing replay entries or stepping the agent."""
    cfg = dqn_lib.DQNConfig(buffer_size=32, batch_size=8)
    p = envs.EnvParams(horizon=8, budget=1e-6)     # done after 1 step
    agent0 = dqn_lib.init_dqn(jax.random.PRNGKey(0), cfg)
    agent, aux = ctl.train_on_env(jax.random.PRNGKey(1), agent0, cfg, p,
                                  episodes=3, scan=True)
    assert np.asarray(aux["ep_len"]).tolist() == [1, 1, 1]
    assert int(agent.step) == 3                    # one TD step per episode
    assert int(agent.replay.ptr) == 3


# --------------------------------------------------------------------- #
# masked median (the rule that joins the padded fused round)
# --------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=2, max_value=9),
       st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=10 ** 6))
def test_masked_median_matches_dense(n_clients, n_valid, seed):
    from repro.core.robust import (coordinate_median,
                                   masked_coordinate_median)
    n_valid = min(n_valid, n_clients)
    rng = np.random.default_rng(seed)
    mask = np.zeros(n_clients, bool)
    mask[rng.choice(n_clients, n_valid, replace=False)] = True
    tree = {"w": jnp.asarray(rng.normal(size=(n_clients, 5, 2)),
                             jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n_clients, 4)), jnp.float32)}
    got = masked_coordinate_median(tree, jnp.asarray(mask))
    dense = coordinate_median(
        jax.tree.map(lambda l: l[np.where(mask)[0]], tree))
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(dense[k]), atol=1e-6)


def test_median_joins_padded_fused_round():
    data, parts = _data(seed=3)
    spec = _spec(3, ControllerSpec("fixed", {"a": 3}),
                 n_clusters=2, aggregator=AggregatorSpec("median"),
                 fleet=FleetSpec(n_devices=8, malicious_frac=0.25))
    fed = Federation.from_spec(spec, data=data, parts=parts)
    assert fed.engine._padded            # one compile, not one per size
    trace = fed.run(eval_every=1.0, max_rounds=12)
    assert trace.records and all(np.isfinite(r.loss) for r in trace.records)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=2, max_value=9),
       st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=10 ** 6))
def test_masked_trimmed_mean_matches_dense(n_clients, n_valid, seed):
    from repro.core.robust import masked_trimmed_mean, trimmed_mean
    n_valid = min(n_valid, n_clients)
    rng = np.random.default_rng(seed)
    mask = np.zeros(n_clients, bool)
    mask[rng.choice(n_clients, n_valid, replace=False)] = True
    tree = {"w": jnp.asarray(rng.normal(size=(n_clients, 5, 2)),
                             jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n_clients, 4)), jnp.float32)}
    got = masked_trimmed_mean(tree, jnp.asarray(mask))
    dense = trimmed_mean(
        jax.tree.map(lambda l: l[np.where(mask)[0]], tree))
    for k in tree:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(dense[k]), atol=1e-6)


def test_trimmed_mean_joins_padded_fused_round_and_scan():
    """`supports_mask=True` via the ±inf-padded sort: trimmed_mean shares
    the padded fixed-shape round and is accepted by execution='scanned'."""
    data, parts = _data(seed=3)
    spec = _spec(3, ControllerSpec("fixed", {"a": 3}),
                 n_clusters=2, aggregator=AggregatorSpec("trimmed_mean"),
                 fleet=FleetSpec(n_devices=8, malicious_frac=0.25),
                 execution="scanned", rounds=6)
    fed = Federation.from_spec(spec, data=data, parts=parts)
    assert fed.engine._padded            # one compile, not one per size
    trace = fed.run()                    # the lax.scan-over-rounds path
    assert len(trace.records) == 7       # 6 rounds + final eval
    assert all(np.isfinite(r.loss) for r in trace.records)


# --------------------------------------------------------------------- #
# run_scanned(K) == event-heap run at a fixed seed
# --------------------------------------------------------------------- #
def _assert_trace_parity(spec, data, parts, K, controller=None):
    mk = (lambda: None) if controller is None else controller
    event = Federation.from_spec(spec, data=data, parts=parts,
                                 controller=mk()).run(
        eval_every=0.0, max_rounds=K)        # record every round
    scanned = Federation.from_spec(spec, data=data, parts=parts,
                                   controller=mk())
    tr = scanned.engine.run_scanned(K)
    rows = tr.records[:K]
    assert len(event.records) == K and len(tr.records) == K + 1
    # scheduling and counters: bit-for-bit
    assert [r.cluster for r in event.records] == [r.cluster for r in rows]
    assert [r.a for r in event.records] == [r.a for r in rows]
    assert [r.agg_count for r in event.records] == \
           [r.agg_count for r in rows]
    # float reductions: to the ulp (f32 event-time accumulation in the
    # scan vs the heap's f64 python floats)
    np.testing.assert_allclose(event.times, [r.t for r in rows], rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(event.energies, [r.energy for r in rows],
                               rtol=1e-6)
    return scanned


def test_run_scanned_parity_fixed_controller():
    data, parts = _data(seed=9)
    spec = _spec(9, ControllerSpec("fixed", {"a": 4}))
    _assert_trace_parity(spec, data, parts, K=14)


def test_run_scanned_parity_lyapunov_controller():
    data, parts = _data(seed=11)
    spec = _spec(11, ControllerSpec("lyapunov",
                                    {"budget": 600.0, "horizon": 60}))
    fed = _assert_trace_parity(spec, data, parts, K=14)
    # the deficit queue lives in FleetState and the host controller adopted
    # it after the scan
    q_leaf = float(fed.engine.state.queue)
    assert q_leaf == float(fed.engine.controller.queue.q)


def test_run_scanned_parity_dqn_controller():
    """The needs_obs=True branch: in-scan `_scan_obs` + `dqn_policy` pick
    the same actions as the host `_obs` + `DQNController.select` (both run
    the same jnp observation builder and greedy head)."""
    from repro.api.components import DQNController
    data, parts = _data(seed=13)
    ctl = DQNController.pretrain(seed=0, episodes=1, horizon=8)
    spec = _spec(13, ControllerSpec("fixed", {"a": 3}))   # overridden below
    _assert_trace_parity(spec, data, parts, K=10,
                         controller=lambda: DQNController(ctl.agent,
                                                          ctl.cfg))


def test_scanned_queue_leaf_matches_host_queue():
    """Event-heap run: the in-jit Eqn-12 leaf advances with the realized
    consumption exactly as the host controller's observe() does."""
    data, parts = _data(seed=5)
    spec = _spec(5, ControllerSpec("lyapunov",
                                   {"budget": 200.0, "horizon": 40}))
    fed = Federation.from_spec(spec, data=data, parts=parts)
    fed.run(eval_every=1e9, max_rounds=10)
    assert float(fed.engine.state.queue) == \
           float(fed.engine.controller.queue.q)


def test_run_scanned_rejects_exact_shape_aggregators():
    data, parts = _data(seed=2)
    spec = _spec(2, ControllerSpec("fixed", {"a": 2}), n_clusters=2,
                 aggregator=AggregatorSpec("multi_krum"))
    fed = Federation.from_spec(spec, data=data, parts=parts)
    with pytest.raises(ValueError, match="supports_mask=False"):
        fed.engine.run_scanned(4)


def test_spec_execution_field():
    with pytest.raises(ValueError, match="unknown execution"):
        FederationSpec(execution="warp").validate()
    with pytest.raises(ValueError, match="no masked variant"):
        FederationSpec(execution="scanned",
                       aggregator=AggregatorSpec("krum")).validate()
    with pytest.raises(ValueError, match="device-scale only"):
        FederationSpec(execution="scanned", scale=api.DATACENTER_SCALE,
                       task=api.TaskSpec("lm")).validate()
    # spec-driven scanned run through the facade
    data, parts = _data(seed=4)
    spec = _spec(4, ControllerSpec("fixed", {"a": 2}), n_clusters=2,
                 execution="scanned", rounds=6)
    trace = Federation.from_spec(spec, data=data, parts=parts).run()
    assert len(trace.records) == 7           # 6 rounds + final eval
    assert trace.records[-1].acc is not None
    assert "adaptive-scanned" in api.SCENARIOS


# --------------------------------------------------------------------- #
# policies
# --------------------------------------------------------------------- #
def _obs(loss=1.0, rnd=0, good=1.0, queue=0.0, obs48=None):
    return ctl.CtlObs(
        round=jnp.int32(rnd), cluster=jnp.int32(0),
        queue=jnp.float32(queue), cluster_loss=jnp.float32(loss),
        cluster_freq=jnp.float32(1.0), mean_freq=jnp.float32(1.0),
        channel_good_frac=jnp.float32(good), energy_used=jnp.float32(0.0),
        dqn_obs=jnp.zeros(48) if obs48 is None else obs48)


def test_lyapunov_policy_backs_off_under_deficit():
    pol = ctl.lyapunov_policy(n_actions=10)
    a_free, _ = pol.step(pol.state, _obs(loss=2.0, queue=0.0))
    a_broke, _ = pol.step(pol.state, _obs(loss=2.0, queue=1e4))
    assert int(a_broke) == 1 <= int(a_free)
    assert int(a_free) > 1               # no deficit: invest in training


def test_table_policy_matches_dqn_on_grid_points():
    cfg = dqn_lib.DQNConfig()
    agent = dqn_lib.init_dqn(jax.random.PRNGKey(7), cfg)
    table = ctl.distill_table(agent.eval_params, loss_bins=6, round_bins=4,
                              good_bins=3)
    dqn = ctl.dqn_policy(agent.eval_params)
    tab = ctl.table_policy(table)
    from repro.control.policy import _grid_obs
    for i, loss in enumerate(np.asarray(table.loss_grid)):
        g = float(table.good_grid[0])
        o = _grid_obs(jnp.float32(loss), jnp.float32(0.0), jnp.float32(g),
                      loss_max=2.3, horizon=100.0)
        a_net, _ = dqn.step(dqn.state, _obs(loss=loss, rnd=0, good=g,
                                            obs48=o))
        a_tab, _ = tab.step(tab.state, _obs(loss=loss, rnd=0, good=g))
        assert int(a_tab) == int(a_net) == int(table.table[i, 0, 0])
