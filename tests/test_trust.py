"""Unit + property tests for the trust/aggregation core (Eqns 4-6, 19)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container image has no hypothesis
    from _propcheck import given, settings, strategies as st

from repro.core import trust
from repro.core.twin import TwinState, init_twins, sample_deviation


def _twins(n, key=0):
    return sample_deviation(jax.random.PRNGKey(key + 1),
                            init_twins(jax.random.PRNGKey(key), n))


class TestLearningQuality:
    def test_outlier_gets_low_quality(self):
        upd = np.tile(np.ones(16), (8, 1)).astype(np.float32)
        upd[3] = 50.0                      # malicious/lazy outlier
        q = trust.learning_quality(jnp.asarray(upd))
        assert q[3] == q.min()
        assert (q[np.arange(8) != 3] > q[3]).all()

    def test_range(self):
        upd = jax.random.normal(jax.random.PRNGKey(0), (6, 32))
        q = trust.learning_quality(upd)
        assert (q > 0).all() and (q <= 1).all()


class TestGradientDiversity:
    def test_sybils_downweighted(self):
        key = jax.random.PRNGKey(0)
        upd = jax.random.normal(key, (6, 64))
        upd = upd.at[4].set(upd[5] * 1.001)    # coordinated pair
        d = trust.gradient_diversity(upd)
        assert d[4] < d[0] and d[5] < d[0]


class TestAggregation:
    def test_trust_weighted_average_matches_manual(self):
        key = jax.random.PRNGKey(1)
        tree = {"a": jax.random.normal(key, (4, 3, 5)),
                "b": jax.random.normal(key, (4, 7))}
        w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
        out = trust.trust_weighted_average(tree, w)
        want = sum(w[i] * tree["a"][i] for i in range(4))
        # atol floor: jnp.sum reduces in a different order than the python
        # sum(), so near-zero coordinates differ by ~1 ulp of the summands
        np.testing.assert_allclose(out["a"], want, rtol=1e-6, atol=1e-7)

    def test_time_weighted_decay_monotonic(self):
        tree = {"a": jnp.stack([jnp.ones(4) * i for i in range(3)])}
        stale = jnp.asarray([0.0, 1.0, 2.0])
        _, w = trust.time_weighted_average(tree, stale)
        assert w[0] > w[1] > w[2] > 0
        np.testing.assert_allclose(float(w.sum()), 1.0, rtol=1e-6)

    @given(st.integers(2, 12), st.integers(0, 10 ** 6))
    @settings(max_examples=20, deadline=None)
    def test_weights_form_simplex(self, n, seed):
        rep = jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=-1.0,
                                 maxval=5.0)
        w = trust.trust_weights(rep)
        assert float(w.sum()) == pytest.approx(1.0, abs=1e-5)
        assert (np.asarray(w) >= 0).all()

    @given(st.integers(2, 8), st.integers(0, 10 ** 6))
    @settings(max_examples=20, deadline=None)
    def test_aggregation_is_convex_combination(self, n, seed):
        """Aggregated params stay inside the per-coordinate client hull."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (n, 16))
        rep = jax.random.uniform(jax.random.fold_in(key, 1), (n,)) + 0.01
        w = trust.trust_weights(rep)
        agg = trust.trust_weighted_average(x, w)
        assert (np.asarray(agg) <= np.asarray(x.max(0)) + 1e-5).all()
        assert (np.asarray(agg) >= np.asarray(x.min(0)) - 1e-5).all()


class TestBelief:
    def test_low_deviation_higher_belief(self):
        tw = _twins(4)
        tw = tw._replace(freq_dev=jnp.asarray([0.01, 0.1, 0.2, 0.3]),
                         dev_estimate=jnp.zeros(4))
        q = jnp.ones(4) * 0.5
        b = trust.belief(tw, q, pkt_fail=0.05)
        assert b[0] > b[1] > b[2] > b[3]

    def test_malicious_interactions_reduce_belief(self):
        tw = _twins(2)
        tw = tw._replace(freq_dev=jnp.ones(2) * 0.1,
                         dev_estimate=jnp.zeros(2),
                         alpha=jnp.asarray([10.0, 10.0]),
                         beta=jnp.asarray([0.0, 20.0]))
        b = trust.belief(tw, jnp.ones(2) * 0.5, pkt_fail=0.05)
        assert b[0] > b[1]
