"""DQN agent (Algorithm 1) tests: mechanics + learning on a known MDP."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dqn as D
from repro.core import envs


def test_epsilon_growth_caps_at_one():
    cfg = D.DQNConfig(eps0=0.1, eps_growth=0.01)
    assert abs(float(D.epsilon(cfg, jnp.int32(0))) - 0.1) < 1e-6
    assert float(D.epsilon(cfg, jnp.int32(200))) == 1.0


def test_replay_ring_buffer_wraps():
    cfg = D.DQNConfig(buffer_size=4, state_dim=3, n_actions=2)
    st = D.init_dqn(jax.random.PRNGKey(0), cfg)
    for i in range(6):
        st = D.store(st, jnp.full((3,), i, jnp.float32), jnp.int32(0),
                     jnp.float32(i), jnp.zeros(3))
    assert bool(st.replay.full)
    assert float(st.replay.r[0]) == 4.0 and float(st.replay.r[1]) == 5.0


def test_target_net_syncs_periodically():
    cfg = D.DQNConfig(target_sync=2, state_dim=4, n_actions=3,
                      buffer_size=32, batch_size=8)
    st = D.init_dqn(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    for i in range(3):
        st = D.store(st, jnp.ones(4), jnp.int32(0), jnp.float32(1.0),
                     jnp.ones(4))
    st1, _ = D.train_step(key, st, cfg)           # step 0: sync
    d0 = float(jnp.abs(st1.eval_params["w1"] - st1.target_params["w1"]).max())
    st2, _ = D.train_step(key, st1, cfg)          # step 1: no sync
    d1 = float(jnp.abs(st2.eval_params["w1"] - st2.target_params["w1"]).max())
    assert d1 > 0.0                               # eval moved away


def test_dqn_learns_bandit():
    """2-state MDP where action 1 always gives +1: Q(a=1) must dominate."""
    cfg = D.DQNConfig(state_dim=4, n_actions=2, buffer_size=256,
                      batch_size=32, lr=5e-3, gamma=0.5)
    st = D.init_dqn(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    s = jnp.ones(4)
    for i in range(300):
        key, k = jax.random.split(key)
        a = int(jax.random.randint(k, (), 0, 2))
        r = 1.0 if a == 1 else 0.0
        st = D.store(st, s, jnp.int32(a), jnp.float32(r), s)
        st, loss = D.train_step(k, st, cfg)
    q = D.q_values(st.eval_params, s)
    assert float(q[1]) > float(q[0])


def test_env_episode_and_budget():
    p = envs.EnvParams(horizon=5, budget=1e9)
    s, obs = envs.reset(jax.random.PRNGKey(0), p)
    assert obs.shape == (envs.OBS_DIM,)
    done = False
    steps = 0
    while not done and steps < 10:
        s, obs, r, done, info = envs.step(s, jnp.int32(3), p)
        steps += 1
    assert steps == 5                              # horizon reached

def test_env_more_local_steps_drop_loss_faster():
    p = envs.EnvParams(horizon=30, noise=0.0)
    s1, _ = envs.reset(jax.random.PRNGKey(0), p)
    s9, _ = envs.reset(jax.random.PRNGKey(0), p)
    for _ in range(10):
        s1, *_ = envs.step(s1, jnp.int32(0), p)    # a=1
        s9, *_ = envs.step(s9, jnp.int32(9), p)    # a=10
    assert float(s9.loss) < float(s1.loss)
