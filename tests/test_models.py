"""Model substrate tests: forward/grad sanity, prefill/decode consistency,
chunked attention equivalence, optimizer behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ArchConfig, decode_step, forward, init_cache,
                          init_params, lm_loss, prefill, weighted_lm_loss)
from repro.models.config import LOCAL, MAMBA, RGLRU
from repro.optim import adafactor, adam, apply_updates, sgd

KEY = jax.random.PRNGKey(0)

DENSE = ArchConfig(name="d", arch_type="dense", num_layers=3, d_model=64,
                   vocab_size=128, num_heads=4, num_kv_heads=2, d_ff=128)
SSM = ArchConfig(name="s", arch_type="ssm", num_layers=3, d_model=64,
                 vocab_size=128, block_pattern=(MAMBA,), ssm_state=8)
HYB = ArchConfig(name="h", arch_type="hybrid", num_layers=5, d_model=64,
                 vocab_size=128, num_heads=4, num_kv_heads=1, d_ff=128,
                 block_pattern=(RGLRU, RGLRU, LOCAL), window=8, lru_width=64)
MOE = ArchConfig(name="m", arch_type="moe", num_layers=3, d_model=64,
                 vocab_size=128, num_heads=4, num_kv_heads=2, d_ff=128,
                 num_experts=4, topk=2, moe_d_ff=32, num_shared_experts=1,
                 first_dense_layers=1)


def _consistency(cfg, S=24, audio=False, tol=2e-2):
    p = init_params(KEY, cfg)
    shape = (1, S + 1) if not audio else (1, cfg.num_codebooks, S + 1)
    toks = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    full, _ = forward(p, cfg, toks, remat=False)
    lg, cache = prefill(p, cfg, toks[..., :S], cache_len=32, q_chunk=8)
    ref = full[:, S - 1] if not audio else full[:, :, S - 1]
    assert float(jnp.max(jnp.abs(lg - ref))) < tol
    lg2, _ = decode_step(p, cache, cfg, toks[..., S], jnp.int32(S))
    ref2 = full[:, S] if not audio else full[:, :, S]
    assert float(jnp.max(jnp.abs(lg2 - ref2))) < tol


class TestConsistency:
    def test_dense(self):
        _consistency(DENSE)

    def test_ssm(self):
        _consistency(SSM)

    def test_hybrid(self):
        _consistency(HYB)

    def test_moe(self):
        # top-k routing flips under bf16 cache noise -> looser tolerance
        _consistency(MOE, tol=0.5)


def test_chunked_attention_matches_unchunked():
    p = init_params(KEY, DENSE)
    toks = jax.random.randint(KEY, (2, 32), 0, 128)
    a, _ = forward(p, DENSE, toks, remat=False, q_chunk=0)
    b, _ = forward(p, DENSE, toks, remat=False, q_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_remat_matches_no_remat():
    p = init_params(KEY, DENSE)
    toks = jax.random.randint(KEY, (2, 16), 0, 128)
    batch = {"tokens": toks, "labels": (toks + 1) % 128}
    g1 = jax.grad(lm_loss)(p, DENSE, batch, remat=True)
    g2 = jax.grad(lm_loss)(p, DENSE, batch, remat=False)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_weighted_loss_reduces_to_plain_with_uniform_weights():
    cfg = DENSE
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (4, 16), 0, 128)
    batch = {"tokens": toks, "labels": (toks + 1) % 128}
    plain = lm_loss(p, cfg, batch, remat=False)
    w = jnp.ones((4,))
    weighted = weighted_lm_loss(p, cfg, batch, w, remat=False)
    assert float(abs(plain - weighted)) < 1e-5


def test_weighted_loss_ignores_zero_weight_client():
    """Trust weighting (mode B): zero-weight examples contribute no grad."""
    cfg = DENSE
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (4, 16), 0, 128)
    batch = {"tokens": toks, "labels": (toks + 1) % 128}
    w = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    g = jax.grad(weighted_lm_loss)(p, cfg, batch, w, remat=False)
    batch3 = {"tokens": toks[:3], "labels": (toks[:3] + 1) % 128}
    g3 = jax.grad(lm_loss)(p, cfg, batch3, remat=False)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


class TestOptimizers:
    def _quad(self, opt, steps=200):
        params = {"x": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(steps):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        return float(jnp.abs(params["x"]).max())

    def test_sgd_converges(self):
        assert self._quad(sgd(0.1, momentum=0.9)) < 1e-2

    def test_adam_converges(self):
        assert self._quad(adam(0.1)) < 1e-2

    def test_adafactor_converges(self):
        # adafactor's clipped relative updates oscillate within ~lr of the
        # optimum; use a small lr and a matching tolerance
        assert self._quad(adafactor(0.02), steps=400) < 0.05

    def test_adafactor_state_is_factored(self):
        opt = adafactor(1e-2)
        params = {"w": jnp.zeros((64, 32))}
        st = opt.init(params)
        assert st["acc"]["w"]["r"].shape == (64,)
        assert st["acc"]["w"]["c"].shape == (32,)
