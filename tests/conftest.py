import jax
import pytest

# Smoke tests and benches see the single real CPU device; only
# launch/dryrun.py forces 512 host devices (and runs in its own process).


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
